"""Shared benchmark helpers: model setup, outlier injection, eval metric.

The HumanEval-pass@1 of the paper is not computable offline; its offline
analog here is (i) the whole-model weighted quantization loss — the paper's
own search objective, Table 4 reports it alongside pass@1 — and (ii) the
relative logit error / argmax agreement of the quantized model vs FP on a
held-out synthetic eval set.  Models get INJECTED activation-outlier
channels so the >6.7B outlier regime (the paper's entire premise) is present
at smoke scale.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import QuantConfig
from repro.core import calibration as C
from repro.models import api

GROUP = 16  # smoke-scale quant group (prod: 128)

# PTQ artifacts shared across suites (and across `benchmarks.run` processes —
# CI runs one process per suite in the same workspace): keyed by the config
# fingerprint, so every (model config, QuantConfig) pair quantizes exactly
# once per workspace and every later suite boots warm from the artifact.
BENCH_PTQ_CACHE = Path(".bench_ptq_cache")


def cached_ptq(cfg, params, calib, qcfg, *, step: float = 0.5,
               cache_root=None):
    """Build-once / serve-many PTQ for benchmarks.

    Quantizes through the artifact cache: a cache miss runs the full
    SmoothQuant+ recipe and saves the artifact (``cold_boot_s``); the
    returned tree is then *always* deserialized from disk (``warm_boot_s``),
    so every caller exercises the save→load round trip and the two numbers
    are directly comparable.  Returns ``(qparams, report, boot)`` where
    ``boot`` is a JSON-ready dict (``cold_boot_s`` is None on a cache hit).
    """
    from repro.core import apply as AP

    art = Path(cache_root or BENCH_PTQ_CACHE) / AP.ptq_fingerprint(cfg, qcfg)
    cold_s = None
    if not AP.ptq_matches(art, cfg, qcfg):
        t0 = time.perf_counter()
        qp, rep = AP.smoothquant_plus(params, cfg, calib, qcfg, step=step)
        AP.save_ptq(art, qp, rep, cfg, qcfg)
        cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    qp, rep = AP.load_ptq(art, cfg, qcfg)
    warm_s = time.perf_counter() - t0
    boot = {"ptq_artifact": str(art),
            "cold_boot_s": cold_s, "warm_boot_s": warm_s}
    return qp, rep, boot


def outlier_model(arch: str, seed: int = 0, hot_scale: float = 100.0):
    cfg = get_config(arch, smoke=True).with_(dtype="float32")
    params = api.init_model(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    hot = np.ones(cfg.d_model, np.float32)
    hot[rng.choice(cfg.d_model, size=max(2, cfg.d_model // 32), replace=False)] = hot_scale
    if "embed" in params:
        params["embed"]["table"] = params["embed"]["table"] * hot[None, :]
    else:  # whisper
        params["dec"]["embed"]["table"] = params["dec"]["embed"]["table"] * hot[None, :]
    return cfg, params


def eval_batches(cfg, n=3, seq=32, seed=99):
    return C.synthetic_calibration_set(cfg, n_seqs=n, seq_len=seq,
                                       domain="humaneval", seed=seed)


def rel_err_and_agreement(cfg, params_fp, params_q, batches) -> Tuple[float, float]:
    rels, ags = [], []
    for b in batches:
        ref = np.asarray(api.forward_fn(params_fp, b, cfg, backend="xla"), np.float32)
        got = np.asarray(api.forward_fn(params_q, b, cfg, backend="xla"), np.float32)
        rels.append(np.linalg.norm(got - ref) / max(np.linalg.norm(ref), 1e-9))
        ags.append(float((got.argmax(-1) == ref.argmax(-1)).mean()))
    return float(np.mean(rels)), float(np.mean(ags))


def timed(fn, *args, reps=3) -> Tuple[float, object]:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out  # us
