"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's metric).
Run: ``PYTHONPATH=src python -m benchmarks.run [--quick]``
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as CM
from repro.configs.base import QuantConfig
from repro.core import apply as AP
from repro.core import calibration as C
from repro.core import search as SE
from repro.core.awq import awq_quantize


def bench_table1_accuracy(quick=False):
    """Table 1/4: FP16 vs RTN vs AWQ vs SmoothQuant+ across the Code Llama
    family (smoke-scale; metric = rel logit err ↓ / argmax agreement ↑)."""
    rows = []
    archs = ["codellama-7b"] if quick else ["codellama-7b", "codellama-13b", "codellama-34b"]
    for arch in archs:
        cfg, params = CM.outlier_model(arch)
        calib = CM.eval_batches(cfg, n=2, seq=24, seed=0)
        ev = CM.eval_batches(cfg, n=2, seq=32, seed=7)
        qcfg = QuantConfig(group_size=CM.GROUP)
        t0 = time.perf_counter()
        sq, rep = AP.smoothquant_plus(params, cfg, calib, qcfg, step=0.25)
        t_sq = time.perf_counter() - t0
        t0 = time.perf_counter()
        aw, _ = awq_quantize(params, cfg, calib, qcfg, step=0.25)
        t_awq = time.perf_counter() - t0
        rt = AP.rtn_baseline(params, cfg, qcfg)
        for nm, qp in (("rtn", rt), ("awq", aw), ("sq+", sq)):
            rel, ag = CM.rel_err_and_agreement(cfg, params, qp, ev)
            rows.append((f"table1/{arch}/{nm}", 0.0,
                         f"rel_err={rel:.4f};agree={ag:.3f}"))
        rows.append((f"table1/{arch}/search_speed", t_sq * 1e6,
                     f"sq+_vs_awq_time_ratio={t_sq / max(t_awq, 1e-9):.2f}"))
    return rows


def bench_table3_calibration_sensitivity(quick=False):
    """Table 3: calibration-domain sensitivity (humaneval/pile/c4 analogs)."""
    rows = []
    cfg, params = CM.outlier_model("codellama-7b")
    ev = CM.eval_batches(cfg, n=2, seq=32, seed=7)
    for dom in ("humaneval", "pile", "c4"):
        calib = C.synthetic_calibration_set(cfg, n_seqs=2, seq_len=24, domain=dom)
        qp, rep = AP.smoothquant_plus(
            params, cfg, calib, QuantConfig(group_size=CM.GROUP), step=0.25)
        rel, ag = CM.rel_err_and_agreement(cfg, params, qp, ev)
        rows.append((f"table3/calib={dom}", 0.0,
                     f"alpha={rep.alpha:.2f};rel_err={rel:.4f};agree={ag:.3f}"))
    return rows


def bench_table4_step_ablation(quick=False):
    """Table 4: search-step ablation (0.05 vs coarser) + loss values."""
    rows = []
    cfg, params = CM.outlier_model("codellama-7b")
    calib = CM.eval_batches(cfg, n=2, seq=24, seed=0)
    col = C.collect_stats(params, cfg, calib)
    for step in (0.05, 0.25, 0.5):
        t0 = time.perf_counter()
        res = SE.search_alpha(params, cfg, col, step=step, group_size=CM.GROUP)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"table4/step={step}", dt,
                     f"alpha={res.alpha:.2f};loss={res.loss:.5f}"))
    return rows


def bench_fig3_layer_loss(quick=False):
    """Fig 3: whole-model quantization loss, smoothed vs unsmoothed."""
    cfg, params = CM.outlier_model("codellama-7b")
    calib = CM.eval_batches(cfg, n=2, seq=24, seed=0)
    col = C.collect_stats(params, cfg, calib)
    l0 = SE.model_quant_loss(params, cfg, col, 0.0, CM.GROUP)
    res = SE.search_alpha(params, cfg, col, step=0.25, group_size=CM.GROUP)
    return [("fig3/loss_unsmoothed", 0.0, f"loss={l0:.5f}"),
            ("fig3/loss_smoothed", 0.0,
             f"loss={res.loss:.5f};reduction={1 - res.loss / max(l0, 1e-12):.2%}")]


def bench_fig7_throughput_latency(quick=False):
    """Fig 7: serving throughput & latency, FP vs W4A16, Poisson arrivals."""
    from repro.serving.engine import Request, ServingEngine

    rows = []
    cfg, params = CM.outlier_model("codellama-7b")
    calib = CM.eval_batches(cfg, n=1, seq=16, seed=0)
    qp, _, boot = CM.cached_ptq(cfg, params, calib,
                                QuantConfig(group_size=CM.GROUP), step=0.5)
    rows.append(("fig7/ptq_boot", 0.0,
                 f"cold_s={boot['cold_boot_s']};warm_s={boot['warm_boot_s']:.3f}"))
    rng = np.random.default_rng(0)
    n_req = 6 if quick else 12

    def drive(p, tag):
        eng = ServingEngine(p, cfg, batch_size=4, max_seq=48, backend="xla")
        # Poisson arrivals rebased onto the engine clock, so the engine's
        # TTFT histogram (first_token - arrival) reads sane offsets
        t_arrive = time.perf_counter() + np.cumsum(
            rng.exponential(0.01, n_req))
        reqs = [Request(uid=i,
                        prompt=rng.integers(2, cfg.vocab_size, 8).astype(np.int32),
                        max_tokens=6, arrival_t=float(t_arrive[i]))
                for i in range(n_req)]
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        dt = time.perf_counter() - t0
        tput = stats.decoded_tokens / dt
        # latency from the engine's own timeline-derived histograms — the
        # benchmark no longer runs a second stopwatch over request fields
        lat = eng.metrics_snapshot()["latency"]
        rows.append((f"fig7/{tag}/throughput", dt * 1e6, f"tok_per_s={tput:.1f}"))
        rows.append((f"fig7/{tag}/latency_per_token",
                     lat["itl_s"]["mean"] * 1e6,
                     f"itl_p50_us={lat['itl_s']['p50'] * 1e6:.0f};"
                     f"itl_p99_us={lat['itl_s']['p99'] * 1e6:.0f};"
                     f"ttft_p50_us={lat['ttft_s']['p50'] * 1e6:.0f}"))
        return tput

    t_fp = drive(params, "fp")
    t_q = drive(qp, "w4a16")
    rows.append(("fig7/speedup", 0.0, f"w4_vs_fp={t_q / max(t_fp, 1e-9):.2f}x"))
    return rows


def bench_paged_vs_slotwise_prefill(quick=False):
    """Tentpole benchmark: paged engine with length-bucketed joint prefill
    vs the seed engine's slot-wise B=1 prefill (same paged engine with
    ``prefill_mode="slotwise"``).  Reports throughput and mean/p95 TTFT."""
    from repro.serving.engine import Request, ServingEngine

    rows = []
    cfg, params = CM.outlier_model("codellama-7b")
    rng = np.random.default_rng(0)
    n_req = 7 if quick else 16
    lens = [int(rng.integers(4, 24)) for _ in range(n_req)]

    def make_reqs(base_uid):
        return [Request(uid=base_uid + i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            lens[i]).astype(np.int32),
                        max_tokens=6)
                for i in range(n_req)]

    def drive(mode):
        eng = ServingEngine(params, cfg, batch_size=4, max_seq=64,
                            page_size=16, backend="xla", prefill_mode=mode)

        def wave(reqs):
            d0 = eng.stats.decoded_tokens
            t0 = time.perf_counter()
            for r in reqs:
                r.arrival_t = t0
                eng.submit(r)
            eng.run_until_drained()
            dt = time.perf_counter() - t0
            ttft = np.array([r.first_token_t - r.arrival_t for r in reqs])
            return eng.stats.decoded_tokens - d0, dt, ttft

        wave(make_reqs(1000))          # warm this engine's jit caches
        pb0 = eng.stats.prefill_batches
        decoded, dt, ttft = wave(make_reqs(0))
        tput = decoded / dt
        rows.append((f"serving/{mode}/throughput", dt * 1e6,
                     f"tok_per_s={tput:.1f};"
                     f"prefill_batches={eng.stats.prefill_batches - pb0}"))
        rows.append((f"serving/{mode}/ttft", float(ttft.mean()) * 1e6,
                     f"p95_us={np.percentile(ttft, 95) * 1e6:.0f}"))
        return tput, float(ttft.mean())

    t_slot, ttft_slot = drive("slotwise")
    t_paged, ttft_paged = drive("bucketed")
    rows.append(("serving/paged_speedup", 0.0,
                 f"throughput={t_paged / max(t_slot, 1e-9):.2f}x;"
                 f"ttft={ttft_slot / max(ttft_paged, 1e-9):.2f}x"))
    return rows


def bench_paged_decode(quick=False):
    """Tentpole benchmark: paged decode attention, jnp dense gather vs the
    Pallas fused page-gather kernel, fp16 vs int8 pools.  Reports decode
    tokens/s (gather path timed compiled; the kernel runs interpreted on CPU,
    so its wall time is not meaningful off-TPU and is labeled as such) and
    the analytic KV bytes each impl moves per step.  Results also land in
    ``BENCH_paged_decode.json`` so the perf trajectory is tracked across PRs.
    """
    import json

    from repro.kernels.paged_attention import paged_kv_bytes_per_step
    from repro.models import attention as A
    from repro.serving import kv_cache as KV

    rows, results = [], []
    b, ps, pages = (2, 8, 2) if quick else (4, 8, 4)
    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(0)
    lens = rng.integers(ps, pages * ps, size=b)
    wp = jnp.asarray(lens - 1, jnp.int32)

    for kvq in (False, True):
        cfg, _ = CM.outlier_model("codellama-7b")
        cfg = cfg.with_(kv_quant=kvq)
        p = A.init_gqa(jax.random.PRNGKey(0), cfg)
        pool_host = KV.PagePool(1 + b * pages, ps, b, pages)
        for s in range(b):
            pool_host.alloc(s, pages)
        table = jnp.asarray(pool_host.table())
        pool = A.init_gqa_page_pool(cfg, 1 + b * pages, ps)
        x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model),
                              cfg.jdtype)
        hkv, dh = cfg.num_kv_heads, cfg.hdim
        el = 1 if kvq else np.dtype(cfg.jdtype).itemsize
        row_bytes = 2 * hkv * dh * el + (2 * hkv * 4 if kvq else 0)  # K+V(+s)

        for impl in ("gather", "pallas_interpret" if not on_tpu else "pallas"):
            icfg = cfg.with_(paged_attn_impl=impl)
            fn = jax.jit(lambda x, pool, table, wp, icfg=icfg: A.gqa_decode_paged(
                p, x, wp[:, None], pool, table, wp, icfg, backend="xla")[0])
            us, _ = CM.timed(fn, x, pool, table, wp)
            kbytes = paged_kv_bytes_per_step(
                lens, pages, ps, row_bytes,
                "gather" if impl == "gather" else "pallas")
            tps = b / (us * 1e-6)
            timed_ok = impl == "gather" or on_tpu
            tag = f"paged_decode/{'int8' if kvq else 'fp'}/{impl}"
            rows.append((tag, us,
                         f"tok_per_s={tps:.1f};kv_bytes_per_step={kbytes}"
                         + ("" if timed_ok else ";interpret_untimed")))
            results.append({
                "impl": impl, "kv_quant": kvq, "us_per_step": us,
                "tokens_per_s": tps, "kv_bytes_per_step": kbytes,
                "wall_time_meaningful": timed_ok,
            })

    def _bytes(kvq, kernel):
        return next(r["kv_bytes_per_step"] for r in results
                    if r["kv_quant"] == kvq and (r["impl"] != "gather") == kernel)

    ratios = {
        f"bytes_ratio_gather_over_kernel_{'int8' if kvq else 'fp'}":
            _bytes(kvq, False) / _bytes(kvq, True)
        for kvq in (False, True)
    }
    payload = {
        "suite": "paged_decode",
        "config": {"batch": int(b), "page_size": int(ps),
                   "pages_per_slot": int(pages),
                   "lens": [int(v) for v in lens],
                   "backend": jax.default_backend()},
        "results": results,
        **{k: float(v) for k, v in ratios.items()},
    }
    with open("BENCH_paged_decode.json", "w") as f:
        json.dump(payload, f, indent=2)
    for k, v in ratios.items():
        rows.append((f"paged_decode/{k}", 0.0, f"ratio={v:.2f}x"))
    rows.append(("paged_decode/json", 0.0, "wrote=BENCH_paged_decode.json"))
    return rows


def bench_paged_pressure(quick=False):
    """Tentpole benchmark: lazy page growth + preemption vs worst-case
    reservation, under pools sized at 25/50/75% of the worst case.

    The reservation baseline blocks admission on pages no request may ever
    write (``prompt + max_tokens`` up front), so its concurrency collapses
    with the pool; the lazy engine reserves prompt+1 and grows during decode,
    preempting (swap-out/swap-in, bit-exact) only under real pressure.
    Reports peak/mean concurrency, tok/s, preemptions, and greedy
    token-identity vs an unconstrained engine at every pool size.  Results
    land in ``BENCH_paged_pressure.json`` — CI asserts the lazy engine admits
    strictly more concurrent requests at the 50% pool."""
    import json

    from repro.serving.engine import Request, ServingEngine

    rows, by_frac = [], {}
    cfg, params = CM.outlier_model("codellama-7b")
    b, ps, max_tokens = 4, 4, 12
    n_req = 8 if quick else 16
    rng = np.random.default_rng(0)
    lens = [int(rng.integers(2, 5)) for _ in range(n_req)]   # ≤ 1 page each
    max_seq = max(lens) + max_tokens                         # rounds up to P
    prompts = [rng.integers(2, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    pages_per_slot = -(-max_seq // ps)
    worst = b * pages_per_slot                               # full reservation

    def drive(reservation, num_pages):
        eng = ServingEngine(params, cfg, batch_size=b, max_seq=max_seq,
                            page_size=ps, num_pages=num_pages, backend="xla",
                            reservation=reservation)

        def wave():
            reqs = [Request(uid=i, prompt=p.copy(), max_tokens=max_tokens)
                    for i, p in enumerate(prompts)]
            before = dataclasses.asdict(eng.stats)
            t0 = time.perf_counter()
            for r in reqs:
                eng.submit(r)
            eng.run_until_drained()
            dt = time.perf_counter() - t0
            delta = {k: v - before[k]
                     for k, v in dataclasses.asdict(eng.stats).items()}
            return reqs, dt, delta

        wave()                    # warm the jit caches (decode, swap shapes —
        reqs, dt, d = wave()      # the workload is deterministic)
        assert eng.stats.completed == 2 * n_req
        eng.pager.check_invariants()
        return {
            "tok_per_s": d["decoded_tokens"] / dt,
            "max_concurrency": eng.stats.max_active,
            "mean_concurrency": (d["active_slot_steps"]
                                 / max(d["steps"], 1)),
            "steps": d["steps"],
            "preemptions": d["preemptions"],
            "grown_pages": d["grown_pages"],
            "swapped_out_bytes": d["swapped_out_bytes"],
        }, [r.output for r in reqs]

    # unconstrained greedy reference for the token-identity claim
    _, ref_out = drive("lazy", worst + 1)

    for frac in (0.25, 0.5, 0.75):
        num_pages = max(pages_per_slot, int(worst * frac)) + 1
        cell = {"num_pages": num_pages}
        for reservation in ("worstcase", "lazy"):
            res, out = drive(reservation, num_pages)
            res["greedy_identical"] = out == ref_out
            cell[reservation] = res
            rows.append((
                f"paged_pressure/pool={int(frac * 100)}%/{reservation}",
                0.0,
                f"tok_per_s={res['tok_per_s']:.1f};"
                f"max_conc={res['max_concurrency']};"
                f"mean_conc={res['mean_concurrency']:.2f};"
                f"preemptions={res['preemptions']};"
                f"greedy_identical={res['greedy_identical']}"))
        by_frac[str(frac)] = cell

    payload = {
        "suite": "paged_pressure",
        "config": {"batch": b, "page_size": ps, "max_seq": max_seq,
                   "max_tokens": max_tokens, "n_requests": n_req,
                   "worst_case_pages": worst,
                   "backend": jax.default_backend()},
        "pools": by_frac,
    }
    with open("BENCH_paged_pressure.json", "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("paged_pressure/json", 0.0,
                 "wrote=BENCH_paged_pressure.json"))
    # the claim the redesign exists for: freeing the worst-case reservation
    # converts pool bytes into concurrency at constant outputs
    mid = by_frac["0.5"]
    assert mid["lazy"]["max_concurrency"] > mid["worstcase"]["max_concurrency"], (
        "lazy growth must admit strictly more concurrent requests than "
        f"worst-case reservation at the 50% pool: {mid}")
    assert all(by_frac[f]["lazy"]["greedy_identical"]
               for f in by_frac), "preemption changed greedy outputs"
    return rows


def bench_prefix_reuse(quick=False):
    """Tentpole benchmark: shared-prefix KV cache — N requests sharing a long
    system prompt, cold (first wave populates the block-hash index) vs warm
    (second wave attaches the cached prefix pages and prefills only its
    suffix).  Reports mean TTFT, prefilled tokens, pages shared, hit rate,
    and greedy token-identity between the waves (identical prompts).  Results
    land in ``BENCH_prefix_reuse.json`` — CI asserts warm TTFT < cold TTFT
    with ``greedy_identical: true``."""
    import json

    from repro.serving.engine import Request, ServingEngine

    rows = []
    cfg, params = CM.outlier_model("codellama-7b")
    b, ps, sys_len, tail_len, max_tokens = 4, 8, 48, 8, 6
    # one admission plan covers the whole wave (n_req == batch), so a cold
    # wave is *all*-cold: with more requests than slots, later admissions
    # would match pages the wave's own first batch inserted
    n_req = b
    rng = np.random.default_rng(0)
    eng = ServingEngine(params, cfg, batch_size=b,
                        max_seq=sys_len + tail_len + max_tokens + ps,
                        page_size=ps, num_pages=1 + 16 * b, backend="xla",
                        prefix_cache=True)

    def make(sys_seed):
        r = np.random.default_rng(sys_seed)
        sys_p = r.integers(2, cfg.vocab_size, sys_len).astype(np.int32)
        return [np.concatenate(
            [sys_p, rng.integers(2, cfg.vocab_size, tail_len).astype(np.int32)])
            for _ in range(n_req)]

    def wave(prompts, uid0):
        before = dataclasses.asdict(eng.stats)
        reqs = [Request(uid=uid0 + i, prompt=p.copy(), max_tokens=max_tokens)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        s0 = eng.metrics.histogram("ttft_s").counts()
        for r in reqs:
            r.arrival_t = t0
            eng.submit(r)
        eng.run_until_drained()
        delta = {k: v - before[k]
                 for k, v in dataclasses.asdict(eng.stats).items()}
        # wave-mean TTFT from the engine's own histogram, diffed around the
        # wave (count and sum subtract exactly, so the mean is exact)
        d = eng.metrics.histogram("ttft_s").counts() - s0
        assert d.count == len(reqs)
        return [r.output for r in reqs], float(d.mean), delta

    # warm the jit caches on a throwaway system prompt: one cold wave (full
    # prefill trace) + one warm wave (suffix prefill trace)
    warm_ps = make(100)
    wave(warm_ps, 1000)
    wave(warm_ps, 2000)

    # ms-scale CPU wall times are noisy: run 3 cold/warm wave pairs (each
    # cold wave needs an unseen system prompt; its paired warm wave repeats
    # the exact prompts and must hit) — TTFT is the min of each side, the
    # stat counters are summed over all three pairs
    identical, cold_ttfts, warm_ttfts = True, [], []
    cold_d, warm_d = {}, {}
    for k in range(3):
        prompts = make(7 + k)
        cold_out, ttft_c, d_c = wave(prompts, 10_000 * (k + 1))
        warm_out, ttft_w, d_w = wave(prompts, 10_000 * (k + 1) + 500)
        cold_ttfts.append(ttft_c)
        warm_ttfts.append(ttft_w)
        identical &= warm_out == cold_out
        assert d_c["prefix_hits"] == 0, d_c       # cold wave is all-cold
        assert d_w["prefix_hits"] == n_req, d_w   # warm wave is all-hit
        for acc, d in ((cold_d, d_c), (warm_d, d_w)):
            for key, v in d.items():
                acc[key] = acc.get(key, 0) + v
    cold_ttft, warm_ttft = min(cold_ttfts), min(warm_ttfts)
    eng.pager.check_invariants()

    cells = {}
    for tag, ttft, d in (("cold", cold_ttft, cold_d),
                         ("warm", warm_ttft, warm_d)):
        cells[tag] = {
            "ttft_best_wave_mean_s": ttft,   # min over waves of wave-mean
            "prefilled_tokens": d["prefilled_tokens"],
            "prefix_hits": d["prefix_hits"],
            "prefix_matched_tokens": d["prefix_matched_tokens"],
            "pages_shared": d["pages_shared"],
            "cow_copies": d["cow_copies"],
        }
        rows.append((f"prefix_reuse/{tag}", ttft * 1e6,
                     f"prefilled={d['prefilled_tokens']};"
                     f"matched={d['prefix_matched_tokens']};"
                     f"pages_shared={d['pages_shared']}"))
    payload = {
        "suite": "prefix_reuse",
        "config": {"batch": b, "page_size": ps, "system_prompt": sys_len,
                   "suffix": tail_len, "n_requests": n_req,
                   "max_tokens": max_tokens,
                   "ttft_metric": "min over 3 wave pairs of per-wave mean",
                   "counters": "summed over the 3 wave pairs",
                   "backend": jax.default_backend()},
        **cells,
        "greedy_identical": identical,
        "ttft_speedup": cold_ttft / max(warm_ttft, 1e-9),
    }
    with open("BENCH_prefix_reuse.json", "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("prefix_reuse/speedup", 0.0,
                 f"ttft={payload['ttft_speedup']:.2f}x;"
                 f"greedy_identical={identical}"))
    rows.append(("prefix_reuse/json", 0.0, "wrote=BENCH_prefix_reuse.json"))
    # the claims the subsystem exists for: a warm prefix makes first tokens
    # strictly cheaper at unchanged greedy outputs
    assert identical, "prefix-cache hits changed greedy outputs"
    assert warm_ttft < cold_ttft, (
        f"warm TTFT {warm_ttft:.4f}s not below cold {cold_ttft:.4f}s")
    return rows


def bench_mixed_prefill(quick=False):
    """Tentpole benchmark: token-budget mixed steps (chunked prefill
    interleaved with decode) vs stop-the-world prefill
    (``max_prefill_tokens=None``).

    A batch of short requests decodes with staggered deadlines; a long
    prompt arrives mid-stream.  Stop-the-world prefills all 32 prompt tokens
    in the admission step, stalling every in-flight decode for the full
    prefill; the mixed engine spreads the prompt over budget-sized chunks,
    each sharing its step with the decode batch.  Reports the p99 and mean
    inter-token latency from the engine's own timeline-derived ITL
    histogram, diffed around each wave (the stall the chunking exists to
    kill shows up as a giant token gap), the long request's TTFT, and greedy
    token-identity between the two modes.  Results land in
    ``BENCH_mixed_prefill.json`` — CI asserts mixed p99 ITL < stop-the-world
    with ``greedy_identical: true``."""
    import json

    from repro.serving.engine import Request, ServingEngine

    rows = []
    cfg, params = CM.outlier_model("codellama-7b")
    b, ps, budget = 3, 8, 8
    long_len, short_len, mt_long = 48, 6, 4
    mts = (10, 14, 18)          # staggered: slots still decode at admission
    n_waves = 1 if quick else 2
    rng = np.random.default_rng(0)
    short_prompts = [rng.integers(2, cfg.vocab_size, short_len).astype(np.int32)
                     for _ in range(b)]
    long_prompt = rng.integers(2, cfg.vocab_size, long_len).astype(np.int32)

    def drive(budget_):
        eng = ServingEngine(params, cfg, batch_size=b, max_seq=64,
                            page_size=ps, backend="xla",
                            max_prefill_tokens=budget_)

        def wave(uid0):
            shorts = [Request(uid=uid0 + i, prompt=p.copy(), max_tokens=mts[i])
                      for i, p in enumerate(short_prompts)]
            for r in shorts:
                eng.submit(r)
            eng.step()              # shorts admitted and decoding
            long_r = Request(uid=uid0 + 99, prompt=long_prompt.copy(),
                             max_tokens=mt_long)
            long_r.arrival_t = time.perf_counter()
            eng.submit(long_r)
            # inter-token latency from the engine's own timeline-derived
            # ITL histogram: diff the bucket state around the stall window
            # (the engine stays warm across waves, so deltas, not totals) —
            # a decode slot's token gap spanning the long prefill IS the
            # stall the chunking exists to kill
            s0 = eng.metrics.histogram("itl_s").counts()
            eng.run_until_drained()
            d = eng.metrics.histogram("itl_s").counts() - s0
            assert all(r.done_t for r in shorts + [long_r])
            return (shorts + [long_r], d,
                    long_r.first_token_t - long_r.arrival_t)

        wave(1000)                  # warm every jit trace (chunk buckets too)
        outs, p99s, means, ttfts = None, [], [], []
        for k in range(n_waves):
            reqs, itl, ttft = wave(10_000 * (k + 1))
            out = [r.output for r in reqs]
            assert outs is None or out == outs   # waves are deterministic
            outs = out
            p99s.append(float(itl.percentile(0.99)))
            means.append(float(itl.mean))
            ttfts.append(float(ttft))
        eng.pager.check_invariants()
        return outs, {
            # min over waves: ms-scale CPU wall times are noisy, the best
            # wave is the least-perturbed measurement of each mode
            "p99_itl_s": min(p99s),
            "mean_itl_s": min(means),
            "long_ttft_s": min(ttfts),
            "prefill_batches": eng.stats.prefill_batches,
        }

    base_out, base = drive(None)
    mix_out, mix = drive(budget)
    identical = mix_out == base_out
    for tag, cell in (("stop_the_world", base), ("mixed", mix)):
        rows.append((f"mixed_prefill/{tag}", cell["p99_itl_s"] * 1e6,
                     f"p99_itl_us={cell['p99_itl_s'] * 1e6:.0f};"
                     f"mean_itl_us={cell['mean_itl_s'] * 1e6:.0f};"
                     f"ttft_us={cell['long_ttft_s'] * 1e6:.0f}"))
    payload = {
        "suite": "mixed_prefill",
        "config": {"batch": b, "page_size": ps, "max_prefill_tokens": budget,
                   "long_prompt": long_len, "short_prompt": short_len,
                   "short_max_tokens": list(mts), "waves": n_waves,
                   "itl_metric": "min over waves of per-wave p99/mean",
                   "backend": jax.default_backend()},
        "stop_the_world": base,
        "mixed": mix,
        "greedy_identical": identical,
        "p99_itl_speedup": base["p99_itl_s"] / max(mix["p99_itl_s"], 1e-9),
    }
    with open("BENCH_mixed_prefill.json", "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("mixed_prefill/p99_speedup", 0.0,
                 f"stw_over_mixed={payload['p99_itl_speedup']:.2f}x;"
                 f"greedy_identical={identical}"))
    rows.append(("mixed_prefill/json", 0.0, "wrote=BENCH_mixed_prefill.json"))
    # the claims the mixed step exists for: chunking caps the decode stall a
    # long arrival causes, at unchanged greedy outputs
    assert identical, "mixed-step chunking changed greedy outputs"
    assert mix["p99_itl_s"] < base["p99_itl_s"], (
        f"mixed p99 ITL {mix['p99_itl_s']:.4f}s not below stop-the-world "
        f"{base['p99_itl_s']:.4f}s")
    return rows


def bench_chaos(quick=False):
    """Robustness suite: the paged engine under a seeded :class:`FaultPlan`
    (allocator outages, grow faults, pressure spikes, delayed swap drains,
    swap-image corruption, forced prefix evictions, launch failures) across
    mixed GQA/MLA × fp16/int8 workloads, plus a dead-on-arrival deadline
    request and a mid-run cancel.  A non-strict engine must degrade, never
    die: zero hangs, every submitted request terminal with a structured
    ``finish_reason``, pager invariants held after every step, and every
    normally-finished request token-identical to the same workload run with
    no faults.  Results land in ``BENCH_chaos.json`` (asserted by CI)."""
    import json

    from repro.configs import get_config
    from repro.models import api as MAPI
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.faults import FaultPlan, FaultSpec

    rows, cells = [], {}
    combos = [("codellama-7b", False), ("deepseek-v2-236b", True)]
    if not quick:
        combos += [("codellama-7b", True), ("deepseek-v2-236b", False)]
    STEP_CAP = 600

    def make_plan():
        # every site scheduled, all budgets bounded — the run must terminate
        # on retries/requeues alone, with max_steps never the thing that
        # saves it
        return FaultPlan([
            FaultSpec("page_alloc", every=11, times=3),
            FaultSpec("page_grow", prob=0.05, times=3),
            FaultSpec("pool_pressure", step=4, value=2, duration=3),
            FaultSpec("swap_drain", op=0, times=1),
            FaultSpec("swap_corrupt", op=1, times=1),
            FaultSpec("prefix_evict", every=5, times=2),
            FaultSpec("decode_launch", step=6, times=2),
            FaultSpec("prefill_launch", op=2, times=1),
        ], seed=0)

    for arch, kvq in combos:
        cfg = get_config(arch, smoke=True)
        if kvq:
            cfg = cfg.with_(dtype="float32", kv_quant=True)
        params = MAPI.init_model(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(3)
        n_req, max_tokens = 6, 8
        lens = (3, 7, 10, 5)
        prompts = [rng.integers(2, cfg.vocab_size,
                                lens[i % 4]).astype(np.int32)
                   for i in range(n_req)]
        kw = dict(batch_size=3, max_seq=24, page_size=4, num_pages=1 + 7,
                  backend="xla", prefix_cache=True, max_prefill_tokens=8)

        # no-fault reference (same tight pool: faults are the only delta)
        ref = ServingEngine(params, cfg, **kw)
        ref_reqs = [Request(uid=i, prompt=p.copy(), max_tokens=max_tokens)
                    for i, p in enumerate(prompts)]
        for r in ref_reqs:
            ref.submit(r)
        ref.run_until_drained(max_steps=STEP_CAP)

        eng = ServingEngine(params, cfg, **kw, strict=False,
                            fault_plan=make_plan(), max_queue=32)
        reqs = [Request(uid=i, prompt=p.copy(), max_tokens=max_tokens)
                for i, p in enumerate(prompts)]
        doa = Request(uid=900, prompt=prompts[0].copy(), max_tokens=4,
                      deadline_s=0.0)           # expires before any work
        victim = Request(uid=901, prompt=prompts[1].copy(), max_tokens=64)
        extras = [doa, victim]
        for r in reqs + extras:
            eng.submit(r)
        hangs, invariants_held, steps = 0, True, 0
        while eng.queue or any(s is not None for s in eng.slots):
            if steps >= STEP_CAP:
                hangs = 1
                break
            eng.step()
            steps += 1
            if steps == 5:
                eng.cancel(901)
            try:
                eng.pager.check_invariants()
            except AssertionError as e:
                invariants_held = False
                rows.append((f"chaos/{arch}/invariant", 0.0, f"BROKE:{e}"))
                break
        all_terminal = all(r.finish_reason is not None and r.done_t
                           for r in reqs + extras)
        identical = all(
            r.output == ref_r.output
            for r, ref_r in zip(reqs, ref_reqs)
            if r.finish_reason in ("completed", "length"))
        survivors = sum(r.finish_reason in ("completed", "length")
                        for r in reqs)
        # observability reconciliation: every fire the plan ledgered must
        # appear in the engine's labeled fault counter, site by site
        ctr = eng.metrics.counter("faults_fired_total")
        reconcile = (all(ctr.value(site=s) == n
                         for s, n in eng.faults.injected.items())
                     and ctr.total() == eng.stats.faults_injected)
        tag = f"{arch}/{'int8' if kvq else 'fp'}"
        cells[tag] = {
            "steps": steps,
            "hangs": hangs,
            "all_terminal": all_terminal,
            "invariants_held": invariants_held,
            "greedy_identical_unfaulted": identical,
            "survivors": survivors,
            "faults_injected": eng.stats.faults_injected,
            "fault_counters": ctr.snapshot(),
            "fault_counters_reconcile": reconcile,
            "fault_log": [list(e) for e in eng.faults.log],
            "retries": eng.stats.retries,
            "expired": eng.stats.expired,
            "cancelled": eng.stats.cancelled,
            "failed": eng.stats.failed,
            "preemptions": eng.stats.preemptions,
        }
        rows.append((f"chaos/{tag}", 0.0,
                     f"steps={steps};faults={eng.stats.faults_injected};"
                     f"retries={eng.stats.retries};survivors={survivors};"
                     f"expired={eng.stats.expired};"
                     f"cancelled={eng.stats.cancelled};"
                     f"failed={eng.stats.failed};"
                     f"identical={identical}"))

    payload = {
        "suite": "chaos",
        "config": {"combos": [f"{a}/{'int8' if q else 'fp'}"
                              for a, q in combos],
                   "step_cap": STEP_CAP,
                   "backend": jax.default_backend()},
        "cells": cells,
        "hangs": sum(c["hangs"] for c in cells.values()),
        "all_terminal": all(c["all_terminal"] for c in cells.values()),
        "invariants_held": all(c["invariants_held"] for c in cells.values()),
        "greedy_identical_unfaulted": all(
            c["greedy_identical_unfaulted"] for c in cells.values()),
        "faults_injected": sum(c["faults_injected"] for c in cells.values()),
        "fault_counters_reconcile": all(
            c["fault_counters_reconcile"] for c in cells.values()),
    }
    with open("BENCH_chaos.json", "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("chaos/json", 0.0, "wrote=BENCH_chaos.json"))
    # the claims graceful degradation exists for
    assert payload["hangs"] == 0, "a chaos cell hit the step cap (hang)"
    assert payload["all_terminal"], "a request never reached a terminal state"
    assert payload["invariants_held"], "pager invariants broke under faults"
    assert payload["greedy_identical_unfaulted"], (
        "a normally-finished request diverged from its no-fault outputs")
    assert payload["faults_injected"] > 0, "the chaos plan never fired"
    assert payload["fault_counters_reconcile"], (
        "fault-site counters diverged from the plan's injected ledger")
    return rows


def bench_obs_overhead(quick=False):
    """Observability tax: identical serve with ``metrics=True`` vs
    ``metrics=False`` — timelines, latency histograms, and the step journal
    are pure host-side bookkeeping, so decode throughput must stay within
    3% and greedy outputs must be bit-identical.  CPU wall times are noisy
    at smoke scale, so each mode runs several waves and the best (least
    perturbed) wave represents it.  The payload also carries the metrics-on
    engine's timeline-derived latency summary — the numbers the README
    quotes.  Results land in ``BENCH_obs_overhead.json`` (asserted by CI)."""
    import json

    from repro.serving.engine import Request, ServingEngine

    rows = []
    cfg, params = CM.outlier_model("codellama-7b")
    n_req, max_tokens = (8, 8) if quick else (12, 12)
    waves = 3 if quick else 4
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size,
                            int(rng.integers(4, 12))).astype(np.int32)
               for _ in range(n_req)]

    def drive(metrics):
        eng = ServingEngine(params, cfg, batch_size=4, max_seq=32,
                            page_size=8, backend="xla", metrics=metrics)

        def wave(uid0):
            reqs = [Request(uid=uid0 + i, prompt=p.copy(),
                            max_tokens=max_tokens)
                    for i, p in enumerate(prompts)]
            d0 = eng.stats.decoded_tokens
            t0 = time.perf_counter()
            for r in reqs:
                eng.submit(r)
            eng.run_until_drained()
            dt = time.perf_counter() - t0
            return [r.output for r in reqs], (eng.stats.decoded_tokens
                                              - d0) / dt

        wave(100_000)                      # warm the jit caches
        outs, tputs = None, []
        for k in range(waves):
            out, tput = wave(1_000 * (k + 1))
            assert outs is None or out == outs   # waves are deterministic
            outs = out
            tputs.append(tput)
        return eng, outs, max(tputs)

    eng_off, out_off, tput_off = drive(False)
    eng_on, out_on, tput_on = drive(True)
    identical = out_on == out_off
    overhead = max(0.0, 1.0 - tput_on / tput_off)
    snap = eng_on.metrics_snapshot()
    for tag, tput in (("off", tput_off), ("on", tput_on)):
        rows.append((f"obs_overhead/metrics_{tag}", 0.0,
                     f"tok_per_s={tput:.1f}"))
    payload = {
        "suite": "obs_overhead",
        "config": {"batch": 4, "n_requests": n_req,
                   "max_tokens": max_tokens, "waves": waves,
                   "tput_metric": "max over waves (least-perturbed)",
                   "backend": jax.default_backend()},
        "tok_per_s": {"metrics_on": tput_on, "metrics_off": tput_off},
        "overhead_frac": overhead,
        "greedy_identical": identical,
        "latency": snap["latency"],
        "journal_steps": len(eng_on.trace.journal),
        "finished_timelines": len(eng_on.trace.finished),
    }
    with open("BENCH_obs_overhead.json", "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("obs_overhead/tax", 0.0,
                 f"overhead={overhead:.1%};greedy_identical={identical};"
                 f"ttft_p50_us={snap['latency']['ttft_s']['p50'] * 1e6:.0f}"))
    rows.append(("obs_overhead/json", 0.0, "wrote=BENCH_obs_overhead.json"))
    # the claims zero-drift observability exists for
    assert identical, "enabling metrics changed greedy outputs"
    assert overhead <= 0.03, (
        f"observability tax {overhead:.1%} exceeds the 3% budget "
        f"(on={tput_on:.1f} off={tput_off:.1f} tok/s)")
    # every request of every wave (warm wave included) has a TTFT sample
    assert snap["latency"]["ttft_s"]["count"] == (waves + 1) * n_req
    return rows


def bench_hybrid_serving(quick=False):
    """State-leaf serving suite: continuous-batching throughput for the
    hybrid SSM (zamba2: fixed-rows state next to paged attention KV) and
    encoder-decoder (whisper: deduplicated read-only encoder pages) configs,
    each on a tight pool with preemption/swap exercised, against per-request
    B=1 reference engines for a greedy-identity flag.  Reports tok/s, the
    FixedRows bytes swapped to host, and encoder-page dedup counts.  Results
    land in ``BENCH_hybrid_serving.json`` (asserted by CI)."""
    import json

    from repro.configs import get_config
    from repro.models import api as MAPI
    from repro.serving.engine import Request, ServingEngine

    rows, cells = [], {}
    n_req = 4 if quick else 6
    max_tokens = 6
    STEP_CAP = 600

    for arch in ("zamba2-7b", "whisper-medium"):
        cfg = get_config(arch, smoke=True)
        params = MAPI.init_model(jax.random.PRNGKey(0), cfg)
        enc = bool(cfg.encdec)
        rng = np.random.default_rng(3)
        lens = (5, 9, 7, 12)
        elens = (6, 9, 11, 7)

        def mk(i, uid_base=0):
            fr = None
            if enc:
                # request 1 repeats request 0's audio — admitted in the same
                # wave, so the exact-match encoder page cache dedups it
                # before pool pressure can evict the cached pages
                r = np.random.default_rng(1000 + (0 if i <= 1 else i))
                t = elens[0] if i <= 1 else elens[i % 4]
                fr = (r.standard_normal((t, cfg.d_model)) * 0.1
                      ).astype(np.float32)
            return Request(uid=uid_base + i,
                           prompt=rng.integers(2, cfg.vocab_size,
                                               lens[i % 4]).astype(np.int32),
                           max_tokens=max_tokens, frames=fr)

        reqs = [mk(i) for i in range(n_req)]

        # unbatched per-request reference (same code path, B=1, roomy pool)
        ref_out = []
        for r in reqs:
            ref = ServingEngine(params, cfg, batch_size=1, max_seq=32,
                                backend="xla")
            rr = Request(uid=r.uid, prompt=r.prompt.copy(),
                         max_tokens=r.max_tokens, frames=r.frames)
            ref.submit(rr)
            ref.run_until_drained(max_steps=STEP_CAP)
            ref_out.append(list(rr.output))

        kw = dict(batch_size=3, max_seq=24, page_size=4, backend="xla",
                  max_prefill_tokens=8,
                  num_pages=1 + (14 if enc else 7))
        eng = ServingEngine(params, cfg, **kw)
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        # the admission watermark can keep these pools from exhausting
        # naturally (always for enc-dec, for zamba2 at small request
        # counts); force one mid-decode preemption so the swap path —
        # fixed-rows gather/scatter or enc-page detach/reattach — is
        # always in the timed run
        for _ in range(30):
            eng.step()
            dec = [i for i in eng._active_slots()
                   if eng.pos[i] >= eng.pref_target[i]]
            if len(dec) >= 2:
                eng._preempt(dec[0])
                break
        stats = eng.run_until_drained(max_steps=STEP_CAP)
        dt = time.perf_counter() - t0
        eng.pager.check_invariants()

        identical = [list(r.output) for r in reqs] == ref_out
        cells[arch] = {
            "requests": n_req,
            "decoded_tokens": stats.decoded_tokens,
            "wall_s": dt,
            "tok_per_s": stats.decoded_tokens / dt,
            "greedy_identical": identical,
            "preemptions": stats.preemptions,
            "resumes": stats.resumes,
            "swapped_fixed_bytes": stats.swapped_fixed_bytes,
            "enc_hits": stats.enc_hits,
            "enc_encodes": stats.enc_encodes,
            "state_leaves": list(MAPI.state_leaves(cfg)),
        }
        rows.append((f"hybrid_serving/{arch}", 0.0,
                     f"tok_s={stats.decoded_tokens / dt:.1f};"
                     f"identical={identical};"
                     f"preemptions={stats.preemptions};"
                     f"fixed_bytes={stats.swapped_fixed_bytes};"
                     f"enc_hits={stats.enc_hits}"))

    payload = {
        "suite": "hybrid_serving",
        "config": {"requests": n_req, "max_tokens": max_tokens,
                   "backend": jax.default_backend()},
        "cells": cells,
        "greedy_identical": all(c["greedy_identical"]
                                for c in cells.values()),
        "fixed_swap_bytes": cells["zamba2-7b"]["swapped_fixed_bytes"],
        "enc_dedup_hits": cells["whisper-medium"]["enc_hits"],
    }
    with open("BENCH_hybrid_serving.json", "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("hybrid_serving/json", 0.0,
                 "wrote=BENCH_hybrid_serving.json"))
    # the claims the README makes for state-leaf serving
    assert payload["greedy_identical"], (
        "batched hybrid/enc-dec outputs diverged from the unbatched refs")
    assert cells["zamba2-7b"]["preemptions"] > 0, "zamba2 never preempted"
    assert payload["fixed_swap_bytes"] > 0, "no fixed-rows state was swapped"
    assert payload["enc_dedup_hits"] >= 1, "encoder page dedup never hit"
    return rows


def bench_w4a16_moe(quick=False):
    """Tentpole benchmark: MoE expert compute, dequant-einsum (dense f32
    weights re-inflated in HBM every step — the seed behavior) vs the grouped
    W4A16 path (packed int4 + scales only).  Reports expert-rows/s (the
    dequant-einsum and fused-XLA paths are timed compiled; the Pallas grouped
    kernel runs interpreted on CPU, so its wall time is labeled untimed
    off-TPU) and the ANALYTIC weight bytes each impl moves per step; the
    packed path must move ~¼ the bf16 bytes.  Results land in
    ``BENCH_w4a16_moe.json`` (asserted by CI)."""
    import json

    from repro.core.quantize import dequantize, quantize
    from repro.kernels import ops
    from repro.kernels.w4a16_grouped import grouped_weight_bytes

    rows, results = [], []
    e, c, d, f = (4, 32, 256, 256) if quick else (8, 64, 512, 512)
    g = 128
    on_tpu = jax.default_backend() == "tpu"
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (e, c, d), jnp.float32)
    w = jax.random.normal(kw, (e, d, f), jnp.float32)
    qt = quantize(w, group_size=g)
    w4_bytes, bf16_bytes = grouped_weight_bytes(
        e, d, f, g, scale_bytes=qt.scales.dtype.itemsize)

    impls = [
        # the seed MoE path: dequantize the whole stacked weight, then einsum
        ("dequant_einsum", bf16_bytes, jax.jit(lambda x: jnp.einsum(
            "ecd,edf->ecf", x, dequantize(qt, jnp.float32)))),
        # packed end to end; XLA fuses dequant into the contraction producer
        ("grouped_xla", w4_bytes, jax.jit(
            lambda x: ops.w4a16_grouped_matmul(x, qt, backend="xla"))),
        ("grouped_pallas" if on_tpu else "grouped_interpret", w4_bytes,
         lambda x: ops.w4a16_grouped_matmul(
             x, qt, backend="pallas" if on_tpu else "interpret")),
    ]
    for name, wbytes, fn in impls:
        us, _ = CM.timed(fn, x)
        tps = e * c / (us * 1e-6)
        timed_ok = "interpret" not in name
        rows.append((f"w4a16_moe/{name}", us,
                     f"rows_per_s={tps:.0f};weight_bytes_per_step={wbytes}"
                     + ("" if timed_ok else ";interpret_untimed")))
        results.append({
            "impl": name, "us_per_step": us, "rows_per_s": tps,
            "weight_bytes_per_step": int(wbytes),
            "wall_time_meaningful": timed_ok,
        })

    ratio = w4_bytes / bf16_bytes
    payload = {
        "suite": "w4a16_moe",
        "config": {"experts": e, "capacity": c, "d_in": d, "d_out": f,
                   "group_size": g, "backend": jax.default_backend()},
        "results": results,
        "weight_bytes_ratio_w4_over_bf16": float(ratio),
    }
    with open("BENCH_w4a16_moe.json", "w") as fh:
        json.dump(payload, fh, indent=2)
    rows.append(("w4a16_moe/bytes_ratio", 0.0,
                 f"w4_over_bf16={ratio:.3f}"))
    rows.append(("w4a16_moe/json", 0.0, "wrote=BENCH_w4a16_moe.json"))
    # the roofline claim the kernel exists for: ~¼ the bf16 weight bytes
    assert ratio < 0.32, f"packed path moves {ratio:.2f}x bf16 bytes (want ~0.25)"
    return rows


def bench_w4a8_prefill(quick=False):
    """Tentpole benchmark: long-prompt chunked prefill, A16 vs A8 activations
    at equal outputs.

    One quantized model (from the shared PTQ artifact cache — the A8
    eligibility flags ride the artifact) serves two engines differing only in
    ``cfg.act_quant``; each prefills the same long prompt in token-budget
    chunks and decodes the same number of tokens.  Reports measured prefill
    tok/s and TTFT per mode (CPU wall time — int8 is emulated off-TPU, so
    the *asserted* speedup is the analytic MXU roofline: int8 MACs run 2× the
    bf16 rate on A8-eligible GEMM FLOPs, attention and A16-fallback layers
    unchanged), the whole-model logit deviation A8 vs A16 against the
    accumulated per-layer threshold bound, and the eligibility split (the
    calibrated hot channels must push ≥ 1 layer back to A16).  Results land
    in ``BENCH_w4a8_prefill.json`` (asserted by CI)."""
    import json

    from repro.core import smoothing as SMX
    from repro.core.quantize import QuantizedTensor
    from repro.models import api
    from repro.serving.engine import Request, ServingEngine

    rows = []
    cfg, params = CM.outlier_model("codellama-7b")
    qcfg = QuantConfig(group_size=CM.GROUP)
    calib = CM.eval_batches(cfg, n=2, seq=24, seed=0)
    qp, rep, boot = CM.cached_ptq(cfg, params, calib, qcfg)
    a8cfg = cfg.with_(act_quant="a8_prefill")

    # ----- eligibility split (flags baked into the artifact) -----
    flags = {k: v for k, v in rep.a8_eligibility.items()
             if not k.endswith("wkv_b_absorbed")}
    n_elig = sum(flags.values())
    n_fallback = len(flags) - n_elig

    # ----- analytic MXU roofline (the asserted claim) -----
    # per-token MACs of every quantized GEMM = stacked weight elements;
    # absorbed MLA tensors are decode-only and lm_head runs on one row per
    # chunk — both negligible in a long prefill, excluded
    elig_macs = a16_macs = 0
    for p in rep.quantized_paths:
        node = SMX.tget(qp, p)
        if not isinstance(node, QuantizedTensor):
            continue
        macs = int(node.packed.size) * 2
        if node.a8:
            elig_macs += macs
        else:
            a16_macs += macs
    long_len = 48 if quick else 96
    budget, mt = 24, 4      # chunk budget ≥ ops.A8_MIN_TOKENS: chunks stay A8
    # attention MACs per token, averaged over causal prefill context
    attn_macs = cfg.num_layers * 2 * (long_len // 2) * cfg.num_heads * cfg.hdim
    bf16_cost = elig_macs + a16_macs + attn_macs
    a8_cost = elig_macs / 2 + a16_macs + attn_macs
    analytic_speedup = bf16_cost / a8_cost

    # ----- whole-model logit deviation, A8 vs A16 on the same tree -----
    ev = CM.eval_batches(cfg, n=2, seq=32, seed=7)
    devs = []
    for b in ev:
        l16 = np.asarray(api.forward_fn(qp, b, cfg, backend="xla"), np.float32)
        l8 = np.asarray(api.forward_fn(qp, b, a8cfg, backend="xla"), np.float32)
        devs.append(np.linalg.norm(l8 - l16) / max(np.linalg.norm(l16), 1e-9))
    logit_rel_dev = float(np.max(devs))
    # worst case: per-token int8 errors ≤ threshold accumulate linearly over
    # every A8 GEMM a token crosses (n_elig stacked paths × depth)
    dev_bound = qcfg.a8_threshold * n_elig * cfg.num_layers

    # ----- engine drive: long-prompt chunked prefill at equal outputs -----
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, long_len).astype(np.int32)
    reps = 2 if quick else 3

    def drive(c):
        eng = ServingEngine(qp, c, batch_size=2, max_seq=long_len + 16,
                            page_size=8, backend="xla",
                            max_prefill_tokens=budget)

        def one(uid):
            r = Request(uid=uid, prompt=prompt.copy(), max_tokens=mt,
                        arrival_t=time.perf_counter())
            eng.submit(r)
            while r.done_t is None:
                eng.step()
            return r, r.first_token_t - r.arrival_t
        one(0)                         # warm every chunk-bucket jit trace
        outs, ttfts = [], []
        for k in range(reps):
            r, ttft = one(k + 1)
            outs.append(r.output)
            ttfts.append(ttft)
        assert all(o == outs[0] for o in outs)   # reps are deterministic
        ttft = min(ttfts)              # least-perturbed CPU wall time
        return {"ttft_s": ttft, "prefill_tok_per_s": long_len / ttft,
                "outputs": outs[0]}

    a16 = drive(cfg)
    a8 = drive(a8cfg)
    outputs_identical = a16.pop("outputs") == a8.pop("outputs")

    for tag, cell in (("a16", a16), ("a8_prefill", a8)):
        rows.append((f"w4a8_prefill/{tag}", cell["ttft_s"] * 1e6,
                     f"prefill_tok_per_s={cell['prefill_tok_per_s']:.1f};"
                     f"ttft_us={cell['ttft_s'] * 1e6:.0f};cpu_wall_untimed"))
    payload = {
        "suite": "w4a8_prefill",
        "config": {"arch": cfg.name, "prompt_tokens": long_len,
                   "chunk_budget": budget, "max_tokens": mt, "reps": reps,
                   "group_size": CM.GROUP, "a8_threshold": qcfg.a8_threshold,
                   "backend": jax.default_backend(),
                   "roofline": "int8 MXU = 2x bf16 MACs on eligible GEMMs; "
                               "attention + A16-fallback layers unchanged; "
                               "absorbed-MLA/lm_head excluded (decode-only / "
                               "one row per chunk)"},
        "ptq_boot": boot,
        "a16": a16,
        "a8_prefill": a8,
        "outputs_identical": outputs_identical,
        "measured_prefill_speedup":
            a8["prefill_tok_per_s"] / max(a16["prefill_tok_per_s"], 1e-9),
        "wall_time_meaningful": jax.default_backend() == "tpu",
        "analytic_prefill_speedup": float(analytic_speedup),
        "a8_eligible_paths": n_elig,
        "a16_fallback_paths": n_fallback,
        "a8_eligibility": flags,
        "a8_errors": rep.a8_errors,
        "logit_rel_dev": logit_rel_dev,
        "logit_dev_bound": float(dev_bound),
    }
    with open("BENCH_w4a8_prefill.json", "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("w4a8_prefill/analytic_speedup", 0.0,
                 f"a8_over_a16={analytic_speedup:.2f}x;"
                 f"eligible={n_elig};fallback={n_fallback}"))
    rows.append(("w4a8_prefill/logit_dev", 0.0,
                 f"rel={logit_rel_dev:.4f};bound={dev_bound:.4f}"))
    rows.append(("w4a8_prefill/json", 0.0, "wrote=BENCH_w4a8_prefill.json"))
    # the claims the A8 body exists for
    assert analytic_speedup >= 1.2, (
        f"analytic A8 prefill speedup {analytic_speedup:.2f}x < 1.2x "
        f"(eligible GEMM fraction too small: {n_elig}/{len(flags)} paths)")
    assert logit_rel_dev <= dev_bound, (
        f"A8 logit deviation {logit_rel_dev:.4f} exceeds accumulated "
        f"per-layer bound {dev_bound:.4f}")
    assert n_fallback >= 1, (
        "calibrated outlier channels produced no A16 fallback layer — the "
        "eligibility gate is not exercising")
    return rows


def bench_kernel_w4a16(quick=False):
    """§2.3 kernel: XLA dequant-matmul path vs fp matmul (CPU proxy) + the
    analytic VMEM claim of the Pallas TPU kernel."""
    from repro.core.quantize import quantize
    from repro.kernels import ops
    from repro.kernels.w4a16_matmul import vmem_bytes

    rows = []
    t, ci, co = (64, 512, 512) if quick else (256, 2048, 2048)
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (t, ci), jnp.float32)
    w = jax.random.normal(kw, (ci, co), jnp.float32)
    qt = quantize(w, group_size=128)
    f_fp = jax.jit(lambda x, w: x @ w)
    f_q = jax.jit(lambda x: ops.w4a16_matmul(x, qt, backend="xla"))
    us_fp, _ = CM.timed(f_fp, x, w)
    us_q, _ = CM.timed(f_q, x)
    rows.append(("kernel/fp_matmul", us_fp, f"shape={t}x{ci}x{co}"))
    rows.append(("kernel/w4a16_xla", us_q,
                 f"bytes_ratio={qt.nbytes_quant() / (w.size * 4):.3f}"))
    vb = vmem_bytes(256, 256, 128)
    rows.append(("kernel/vmem_claim", 0.0,
                 f"bytes={vb};fits_16MB={vb < 16 * 2**20}"))
    from repro.kernels.flash_attention import flash_vmem_bytes

    fvb = flash_vmem_bytes(512, 512, 128)
    rows.append(("kernel/flash_vmem_claim", 0.0,
                 f"bytes={fvb};fits_16MB={fvb < 16 * 2**20}"))
    # causal block-skip FLOP saving at 32k prefill (analytic)
    rows.append(("kernel/flash_causal_skip", 0.0,
                 "flop_saving=~2x_on_masked_blocks(useful/HLO 0.42-0.63 -> ~0.85)"))
    return rows


ALL = [
    bench_table1_accuracy,
    bench_table3_calibration_sensitivity,
    bench_table4_step_ablation,
    bench_fig3_layer_loss,
    bench_fig7_throughput_latency,
    bench_paged_vs_slotwise_prefill,
    bench_paged_decode,
    bench_paged_pressure,
    bench_prefix_reuse,
    bench_mixed_prefill,
    bench_chaos,
    bench_obs_overhead,
    bench_hybrid_serving,
    bench_w4a16_moe,
    bench_w4a8_prefill,
    bench_kernel_w4a16,
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="run benches whose name contains this substring")
    ap.add_argument("--suite", default=None, dest="only",
                    help="alias of --only (e.g. --suite paged_decode)")
    args = ap.parse_args()
    wanted = args.only
    print("name,us_per_call,derived")
    failures = 0
    for fn in ALL:
        if wanted and wanted not in fn.__name__:
            continue
        try:
            for name, us, derived in fn(quick=args.quick):
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{fn.__name__},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
