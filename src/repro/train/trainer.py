"""Train-step factory: loss → grad → (optional microbatch accumulation) →
AdamW, with activation remat on the layer scan.  Pure function of
(params, opt_state, batch) so it jits/pjits cleanly."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import api
from repro.optim import adamw


def make_loss_fn(cfg: ModelConfig, tc: TrainConfig, backend: str = "auto"):
    remat = tc.remat != "none"

    def loss_fn(params, batch):
        return api.loss_fn(params, batch, cfg, backend=backend, remat=remat)

    return loss_fn


def _split_microbatches(batch, n: int):
    return [jax.tree.map(lambda a: a[i::n], batch) for i in range(n)]


def make_train_step(cfg: ModelConfig, tc: TrainConfig, backend: str = "auto"):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics)."""
    loss_fn = make_loss_fn(cfg, tc, backend)

    def train_step(params, opt_state, batch):
        if tc.microbatch and tc.microbatch > 1:
            n = tc.microbatch
            mbs = _split_microbatches(batch, n)

            def acc_step(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n, grad_acc, grads
                )
                return (loss_acc + loss / n, grad_acc), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zero),
                jax.tree.map(lambda *xs: jnp.stack(xs), *mbs),
            )
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state, metrics = adamw.adamw_update(
            params, grads, opt_state, tc
        )
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step
