"""Pallas TPU flash attention with causal block-skipping (prefill path).

The chunked jnp attention in ``models/attention.py`` masks the upper
triangle but still *computes* it — a 2× FLOP tax on causal prefill (visible
as useful/HLO ≈ 0.5 on prefill cells).  This kernel skips fully-masked
(q-block, kv-block) tiles with ``pl.when``, so causal prefill does ~half the
MXU work.  GQA is handled without materializing repeated KV heads: the K/V
BlockSpec index maps fold the query-group factor (head ``h`` reads KV head
``h // group``).

Grid: ``(B·H, T/bq, S/bkv)`` with the KV axis innermost; online-softmax
state (m, l, acc) lives in VMEM scratch and flushes on the last KV step.
Validated in interpret mode against the pure-jnp oracle (ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512

from repro.kernels import tpu_compiler_params


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_kv: int, block_q: int, block_kv: int, causal: bool,
            scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skip: kv block strictly above the diagonal does nothing
    q_start = qi * block_q
    k_start = ki * block_kv
    live = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # [bq, D]
        k = k_ref[0].astype(jnp.float32)              # [bkv, D]
        v = v_ref[0].astype(jnp.float32)              # [bkv, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # [bq, bkv]
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,            # [B, T, H, D]
    k: jax.Array,            # [B, S, Hkv, D]
    v: jax.Array,            # [B, S, Hkv, D]
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    b, t, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    if h % hkv != 0:
        raise ValueError(f"H={h} not a multiple of Hkv={hkv}")
    grp = h // hkv
    scale = d ** -0.5

    bq = min(block_q, t)
    bkv = min(block_kv, s)
    tp = -(-t // bq) * bq
    sp = -(-s // bkv) * bkv
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kh = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    if tp != t:
        qh = jnp.pad(qh, ((0, 0), (0, tp - t), (0, 0)))
    if sp != s:
        # pad keys so padded positions never win the softmax
        kh = jnp.pad(kh, ((0, 0), (0, sp - s), (0, 0)),
                     constant_values=0)
        vh = jnp.pad(vh, ((0, 0), (0, sp - s), (0, 0)))
        # padded kv columns are masked via causal (they sit beyond any qpos)
        if not causal:
            raise ValueError("non-causal flash requires S divisible by block_kv")
    n_q, n_kv = tp // bq, sp // bkv

    out = pl.pallas_call(
        functools.partial(
            _kernel, n_kv=n_kv, block_q=bq, block_kv=bkv, causal=causal,
            scale=scale,
        ),
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            # GQA: query head bh reads KV head bh//grp — no repeat in HBM
            pl.BlockSpec((1, bkv, d), lambda bh, i, j, grp=grp: (bh // grp, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, i, j, grp=grp: (bh // grp, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, tp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qh, kh, vh)

    out = out[:, :t].reshape(b, h, t, d).transpose(0, 2, 1, 3)
    return out


def flash_vmem_bytes(block_q: int, block_kv: int, d: int,
                     dtype_bytes: int = 2) -> int:
    """Analytic VMEM working set per grid step (roofline notes)."""
    return (block_q * d * dtype_bytes          # q block
            + 2 * block_kv * d * dtype_bytes   # k + v blocks
            + block_q * d * 4                  # f32 acc
            + 2 * block_q * 4                  # m, l
            + block_q * d * dtype_bytes)       # out
