"""Pallas TPU paged-attention decode kernel: page-table gather fused into
flash attention.

The jnp paged decode path materializes ``gather_pages(pool, table)`` as a
dense ``[B, P*PS, ...]`` array in HBM every step — the pool rows are read,
written back out as the gathered copy, then read *again* by the attention
einsum: ~3× the KV bytes of a single streaming pass, plus an O(batch ×
max-pages) allocation on the memory-bound decode hot path.  This kernel
indexes the pool *inside* the grid instead: the block table rides in as a
scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``), and the K/V
BlockSpec index maps read it to pick the pool page each ``(batch, page)``
grid cell DMAs into VMEM.  No intermediate gather ever exists in HBM, and
pages past a sequence's valid length are clamped to the previous block index
so the pipeline elides their copies — bytes moved scale with *live tokens*,
not ``batch × max_pages``.

Grids
  GQA: ``(B, Hkv, P)``, pages innermost; each cell attends the slot's
  ``grp = H/Hkv`` query heads for one KV head against one page.
  MLA (absorbed form): ``(B, P)``; scores run in the latent space
  (``q_lat·ckv + q_pe·kpe``) so the per-page work covers all H heads.
  Chunked prefill: the same grids with one extra step — ``(B, Hkv, P+1)`` /
  ``(B, P+1)`` — where queries arrive as a ``[B, T_chunk, …]`` block at true
  positions ``prefix_len[b] + t``.  Steps ``0..P-1`` stream the cached
  prefix pages (masked ``kv_pos < prefix_len``, no causal term needed since
  every chunk query postdates the prefix); the final step attends the
  chunk's own raw-fp K/V with a causal-within-chunk mask and flushes.

Online-softmax state (m, l, acc) lives in VMEM scratch, initialized at page
0 and flushed on the last page step (same shape as ``flash_attention``).

Int8 pools: when scale operands are passed, K/V pages are int8 with per-row
(position, head) f32 scales.  Scores are computed on the raw int8 codes
(cast to f32 for the MXU) and the scale is applied to the score/probability
row — identical math to the jnp reference, half the page bytes.

Scalar-prefetch contract (shared with ``serving/kv_cache.py``):
  ``table[B*P]``  flattened block table; entry ``b*P + p`` is the pool page
                  holding logical page ``p`` of batch row ``b`` (freed /
                  unused entries point at the trash page 0);
  ``lengths[B]``  valid rows per batch row, *including* the token written
                  this step (``write_pos + 1``); clamps both the in-page
                  validity mask and the dead-page DMA elision.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

from repro.kernels import tpu_compiler_params


def _live_pages(length, page_size):
    """Number of pages holding valid rows (length >= 1 on every decode)."""
    return (length + page_size - 1) // page_size


# ============================================================== GQA kernel ==
def _gqa_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                page_size: int, n_pages: int, scale: float, quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    page_start = p * page_size

    @pl.when(page_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [grp, Dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [PS, Dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)      # [PS, Dv]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                      # [grp, PS]
        if quant:
            # int8 codes hit the MXU; the per-row scale lands on the (tiny)
            # score row — mirrors the jnp int8 reference exactly
            s = s * ks_ref[0, :, 0][None, :]
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = pos < length
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        pexp = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + pexp.sum(-1, keepdims=True)
        if quant:
            pexp = pexp * vs_ref[0, :, 0][None, :]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def gqa_paged_attention(
    q: jax.Array,               # [B, Hkv, grp, Dh] one decode token
    k_pool: jax.Array,          # [NP, PS, Hkv, Dh] (bf16/f32 or int8)
    v_pool: jax.Array,          # [NP, PS, Hkv, Dv]
    table_rows: jax.Array,      # [B, P] int32 pool page per logical page
    lengths: jax.Array,         # [B] int32 valid rows incl. this step's token
    k_scale: jax.Array | None = None,   # [NP, PS, Hkv] f32 (int8 pools)
    v_scale: jax.Array | None = None,
    *,
    sm_scale: float,
    interpret: bool = False,
) -> jax.Array:                 # [B, Hkv, grp, Dv] f32
    b, hkv, grp, dh = q.shape
    ps = k_pool.shape[1]
    dv = v_pool.shape[-1]
    pages = table_rows.shape[1]
    quant = k_scale is not None
    flat_tbl = table_rows.reshape(-1).astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    def pool_map(bi, hi, pi, tbl, lens):
        # dead pages re-map to the last live page: the pipeline sees the same
        # block index as the previous step and elides the DMA entirely
        pp = jnp.minimum(pi, _live_pages(lens[bi], ps) - 1)
        return (tbl[bi * pages + pp], 0, hi, 0)

    def scale_map(bi, hi, pi, tbl, lens):
        pp = jnp.minimum(pi, _live_pages(lens[bi], ps) - 1)
        return (tbl[bi * pages + pp], 0, hi)

    in_specs = [
        pl.BlockSpec((1, 1, grp, dh), lambda bi, hi, pi, tbl, lens: (bi, hi, 0, 0)),
        pl.BlockSpec((1, ps, 1, dh), pool_map),
        pl.BlockSpec((1, ps, 1, dv), pool_map),
    ]
    operands = [q, k_pool, v_pool]
    if quant:
        in_specs += [
            pl.BlockSpec((1, ps, 1), scale_map),
            pl.BlockSpec((1, ps, 1), scale_map),
        ]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, grp, dv), lambda bi, hi, pi, tbl, lens: (bi, hi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((grp, 1), jnp.float32),
            pltpu.VMEM((grp, 1), jnp.float32),
            pltpu.VMEM((grp, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _gqa_kernel, page_size=ps, n_pages=pages, scale=sm_scale,
            quant=quant,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, grp, dv), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(flat_tbl, lengths, *operands)


# ============================================================== MLA kernel ==
def _mla_kernel(tbl_ref, len_ref, qlat_ref, qpe_ref, ckv_ref, kpe_ref, *rest,
                page_size: int, n_pages: int, scale: float, quant: bool):
    if quant:
        cs_ref, ps_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    page_start = p * page_size

    @pl.when(page_start < length)
    def _compute():
        q_lat = qlat_ref[0].astype(jnp.float32)        # [H, r]
        q_pe = qpe_ref[0].astype(jnp.float32)          # [H, dr]
        ckv = ckv_ref[0].astype(jnp.float32)           # [PS, r]
        kpe = kpe_ref[0].astype(jnp.float32)           # [PS, dr]
        s_lat = jax.lax.dot_general(
            q_lat, ckv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # [H, PS]
        s_pe = jax.lax.dot_general(
            q_pe, kpe, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        if quant:
            s_lat = s_lat * cs_ref[0][None, :]
            s_pe = s_pe * ps_ref[0][None, :]
        s = (s_lat + s_pe) * scale
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = pos < length
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        pexp = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + pexp.sum(-1, keepdims=True)
        if quant:
            # o_lat = Σ p·(s_j·ckv_j) = (p ⊙ s) @ ckv_int8
            pexp = pexp * cs_ref[0][None, :]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pexp, ckv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _flush():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def mla_paged_attention(
    q_lat: jax.Array,           # [B, H, r] absorbed query (q_nope · w_k)
    q_pe: jax.Array,            # [B, H, dr] rope query
    ckv_pool: jax.Array,        # [NP, PS, r] latent pool (bf16/f32 or int8)
    kpe_pool: jax.Array,        # [NP, PS, dr]
    table_rows: jax.Array,      # [B, P] int32
    lengths: jax.Array,         # [B] int32 valid rows incl. this token
    ckv_scale: jax.Array | None = None,  # [NP, PS] f32 (int8 pools)
    kpe_scale: jax.Array | None = None,
    *,
    sm_scale: float,
    interpret: bool = False,
) -> jax.Array:                 # [B, H, r] f32 latent output
    b, h, r = q_lat.shape
    dr = q_pe.shape[-1]
    ps = ckv_pool.shape[1]
    pages = table_rows.shape[1]
    quant = ckv_scale is not None
    flat_tbl = table_rows.reshape(-1).astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    def pool_map(bi, pi, tbl, lens):
        pp = jnp.minimum(pi, _live_pages(lens[bi], ps) - 1)
        return (tbl[bi * pages + pp], 0, 0)

    def scale_map(bi, pi, tbl, lens):
        pp = jnp.minimum(pi, _live_pages(lens[bi], ps) - 1)
        return (tbl[bi * pages + pp], 0)

    in_specs = [
        pl.BlockSpec((1, h, r), lambda bi, pi, tbl, lens: (bi, 0, 0)),
        pl.BlockSpec((1, h, dr), lambda bi, pi, tbl, lens: (bi, 0, 0)),
        pl.BlockSpec((1, ps, r), pool_map),
        pl.BlockSpec((1, ps, dr), pool_map),
    ]
    operands = [q_lat, q_pe, ckv_pool, kpe_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, ps), scale_map),
                     pl.BlockSpec((1, ps), scale_map)]
        operands += [ckv_scale, kpe_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, r), lambda bi, pi, tbl, lens: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, r), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _mla_kernel, page_size=ps, n_pages=pages, scale=sm_scale,
            quant=quant,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, r), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(flat_tbl, lengths, *operands)


# ================================================== chunked prefill (GQA) ==
def _gqa_prefill_kernel(tbl_ref, pfx_ref, cln_ref, q_ref, ksuf_ref, vsuf_ref,
                        k_ref, v_ref, *rest, page_size: int, n_pages: int,
                        t_chunk: int, grp: int, scale: float, quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    prefix = pfx_ref[b]
    chunk = cln_ref[b]
    rows = t_chunk * grp

    def _q_rows():
        return q_ref[0, :, 0].astype(jnp.float32).reshape(rows, -1)

    def _update(s, valid, v, v_row_scale):
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        pexp = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + pexp.sum(-1, keepdims=True)
        if v_row_scale is not None:
            pexp = pexp * v_row_scale
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    # grid steps 0..P-1: the cached prefix, one pool page per step — every
    # chunk query sits at position >= prefix, so the only mask is raggedness
    @pl.when((p < n_pages) & (p * page_size < prefix))
    def _pages():
        q = _q_rows()                                   # [T*grp, Dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # [PS, Dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)       # [PS, Dv]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                       # [T*grp, PS]
        if quant:
            s = s * ks_ref[0, :, 0][None, :]
        pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _update(s, pos < prefix, v,
                vs_ref[0, :, 0][None, :] if quant else None)

    # final grid step: the chunk attends its own raw-fp K/V (the rows being
    # written this step) with a causal-within-chunk mask — no int8 scale, so
    # cold/warm chunks keep the slab-prefill numerics bit-for-bit
    @pl.when(p == n_pages)
    def _suffix():
        q = _q_rows()
        k = ksuf_ref[0, :, 0, :].astype(jnp.float32)    # [T, Dh]
        v = vsuf_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                       # [T*grp, T]
        tq = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // grp
        j = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _update(s, (j <= tq) & (j < chunk), v, None)

    @pl.when(p == n_pages)
    def _flush():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0] = o.reshape(t_chunk, grp, -1)


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def gqa_paged_prefill(
    q: jax.Array,               # [B, T, Hkv, grp, Dh] chunk queries
    k_suf: jax.Array,           # [B, T, Hkv, Dh] raw chunk keys (pre-quant)
    v_suf: jax.Array,           # [B, T, Hkv, Dv]
    k_pool: jax.Array,          # [NP, PS, Hkv, Dh] (bf16/f32 or int8)
    v_pool: jax.Array,          # [NP, PS, Hkv, Dv]
    table_rows: jax.Array,      # [B, P] int32 pool page per logical page
    prefix_len: jax.Array,      # [B] int32 tokens already in the pages
    chunk_len: jax.Array,       # [B] int32 valid rows of this chunk (<= T)
    k_scale: jax.Array | None = None,   # [NP, PS, Hkv] f32 (int8 pools)
    v_scale: jax.Array | None = None,
    *,
    sm_scale: float,
    interpret: bool = False,
) -> jax.Array:                 # [B, T, Hkv, grp, Dv] f32
    """Chunked-prefill attention straight off the paged pools.

    Grid ``(B, Hkv, P+1)``: steps ``0..P-1`` DMA prefix pages by block table
    (dead pages clamp to the last live one, eliding the copy — same contract
    as the decode grid); the final step attends the chunk's own raw-fp
    suffix K/V with a causal mask ``j <= t`` and flushes.  Every query row
    ``t`` sits at true position ``prefix_len[b] + t``, which is >= any
    prefix position, so prefix steps need no causal term.
    """
    b, t, hkv, grp, dh = q.shape
    ps = k_pool.shape[1]
    dv = v_pool.shape[-1]
    pages = table_rows.shape[1]
    quant = k_scale is not None
    flat_tbl = table_rows.reshape(-1).astype(jnp.int32)
    prefix_len = prefix_len.astype(jnp.int32)
    chunk_len = chunk_len.astype(jnp.int32)

    def pool_map(bi, hi, pi, tbl, pfx, cln):
        # clamp past-prefix steps (incl. the suffix step P) to the last live
        # prefix page; max(live, 1) keeps cold rows (prefix 0) in range
        live = jnp.maximum(_live_pages(pfx[bi], ps), 1)
        pp = jnp.minimum(pi, live - 1)
        return (tbl[bi * pages + pp], 0, hi, 0)

    def scale_map(bi, hi, pi, tbl, pfx, cln):
        live = jnp.maximum(_live_pages(pfx[bi], ps), 1)
        pp = jnp.minimum(pi, live - 1)
        return (tbl[bi * pages + pp], 0, hi)

    def fixed(bi, hi, pi, tbl, pfx, cln):
        return (bi, 0, hi, 0)

    in_specs = [
        pl.BlockSpec((1, t, 1, grp, dh),
                     lambda bi, hi, pi, tbl, pfx, cln: (bi, 0, hi, 0, 0)),
        pl.BlockSpec((1, t, 1, dh), fixed),
        pl.BlockSpec((1, t, 1, dv), fixed),
        pl.BlockSpec((1, ps, 1, dh), pool_map),
        pl.BlockSpec((1, ps, 1, dv), pool_map),
    ]
    operands = [q, k_suf, v_suf, k_pool, v_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, ps, 1), scale_map),
                     pl.BlockSpec((1, ps, 1), scale_map)]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, pages + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, t, 1, grp, dv),
            lambda bi, hi, pi, tbl, pfx, cln: (bi, 0, hi, 0, 0),
        ),
        scratch_shapes=[
            pltpu.VMEM((t * grp, 1), jnp.float32),
            pltpu.VMEM((t * grp, 1), jnp.float32),
            pltpu.VMEM((t * grp, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _gqa_prefill_kernel, page_size=ps, n_pages=pages, t_chunk=t,
            grp=grp, scale=sm_scale, quant=quant,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, hkv, grp, dv), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(flat_tbl, prefix_len, chunk_len, *operands)


# ================================================== chunked prefill (MLA) ==
def _mla_prefill_kernel(tbl_ref, pfx_ref, cln_ref, qlat_ref, qpe_ref,
                        csuf_ref, psuf_ref, ckv_ref, kpe_ref, *rest,
                        page_size: int, n_pages: int, t_chunk: int,
                        heads: int, scale: float, quant: bool):
    if quant:
        cs_ref, pscl_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    prefix = pfx_ref[b]
    chunk = cln_ref[b]
    rows = t_chunk * heads

    def _q_rows():
        q_lat = qlat_ref[0].astype(jnp.float32).reshape(rows, -1)
        q_pe = qpe_ref[0].astype(jnp.float32).reshape(rows, -1)
        return q_lat, q_pe

    def _update(s, valid, v, v_row_scale):
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        pexp = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + pexp.sum(-1, keepdims=True)
        if v_row_scale is not None:
            pexp = pexp * v_row_scale
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when((p < n_pages) & (p * page_size < prefix))
    def _pages():
        q_lat, q_pe = _q_rows()                         # [T*H, r], [T*H, dr]
        ckv = ckv_ref[0].astype(jnp.float32)            # [PS, r]
        kpe = kpe_ref[0].astype(jnp.float32)            # [PS, dr]
        s_lat = jax.lax.dot_general(
            q_lat, ckv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        s_pe = jax.lax.dot_general(
            q_pe, kpe, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        if quant:
            s_lat = s_lat * cs_ref[0][None, :]
            s_pe = s_pe * pscl_ref[0][None, :]
        s = (s_lat + s_pe) * scale                      # [T*H, PS]
        pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _update(s, pos < prefix, ckv,
                cs_ref[0][None, :] if quant else None)

    @pl.when(p == n_pages)
    def _suffix():
        q_lat, q_pe = _q_rows()
        ckv_s = csuf_ref[0].astype(jnp.float32)         # [T, r] raw latent
        kpe_s = psuf_ref[0].astype(jnp.float32)         # [T, dr]
        s = (jax.lax.dot_general(
            q_lat, ckv_s, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) + jax.lax.dot_general(
            q_pe, kpe_s, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )) * scale                                      # [T*H, T]
        tq = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // heads
        j = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _update(s, (j <= tq) & (j < chunk), ckv_s, None)

    @pl.when(p == n_pages)
    def _flush():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = o.reshape(t_chunk, heads, -1)


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def mla_paged_prefill(
    q_lat: jax.Array,           # [B, T, H, r] absorbed chunk queries
    q_pe: jax.Array,            # [B, T, H, dr]
    ckv_suf: jax.Array,         # [B, T, r] raw chunk latent (pre-quant)
    kpe_suf: jax.Array,         # [B, T, dr]
    ckv_pool: jax.Array,        # [NP, PS, r] (bf16/f32 or int8)
    kpe_pool: jax.Array,        # [NP, PS, dr]
    table_rows: jax.Array,      # [B, P] int32
    prefix_len: jax.Array,      # [B] int32 tokens already in the pages
    chunk_len: jax.Array,       # [B] int32 valid rows of this chunk (<= T)
    ckv_scale: jax.Array | None = None,  # [NP, PS] f32 (int8 pools)
    kpe_scale: jax.Array | None = None,
    *,
    sm_scale: float,
    interpret: bool = False,
) -> jax.Array:                 # [B, T, H, r] f32 latent output
    """MLA chunked prefill in absorbed form, same grid story as GQA but
    ``(B, P+1)`` — latent scores cover all H heads per page step."""
    b, t, h, r = q_lat.shape
    dr = q_pe.shape[-1]
    ps = ckv_pool.shape[1]
    pages = table_rows.shape[1]
    quant = ckv_scale is not None
    flat_tbl = table_rows.reshape(-1).astype(jnp.int32)
    prefix_len = prefix_len.astype(jnp.int32)
    chunk_len = chunk_len.astype(jnp.int32)

    def pool_map(bi, pi, tbl, pfx, cln):
        live = jnp.maximum(_live_pages(pfx[bi], ps), 1)
        pp = jnp.minimum(pi, live - 1)
        return (tbl[bi * pages + pp], 0, 0)

    def scale_map(bi, pi, tbl, pfx, cln):
        live = jnp.maximum(_live_pages(pfx[bi], ps), 1)
        pp = jnp.minimum(pi, live - 1)
        return (tbl[bi * pages + pp], 0)

    in_specs = [
        pl.BlockSpec((1, t, h, r), lambda bi, pi, tbl, pfx, cln: (bi, 0, 0, 0)),
        pl.BlockSpec((1, t, h, dr), lambda bi, pi, tbl, pfx, cln: (bi, 0, 0, 0)),
        pl.BlockSpec((1, t, r), lambda bi, pi, tbl, pfx, cln: (bi, 0, 0)),
        pl.BlockSpec((1, t, dr), lambda bi, pi, tbl, pfx, cln: (bi, 0, 0)),
        pl.BlockSpec((1, ps, r), pool_map),
        pl.BlockSpec((1, ps, dr), pool_map),
    ]
    operands = [q_lat, q_pe, ckv_suf, kpe_suf, ckv_pool, kpe_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, ps), scale_map),
                     pl.BlockSpec((1, ps), scale_map)]
        operands += [ckv_scale, kpe_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, pages + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, t, h, r), lambda bi, pi, tbl, pfx, cln: (bi, 0, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((t * h, 1), jnp.float32),
            pltpu.VMEM((t * h, 1), jnp.float32),
            pltpu.VMEM((t * h, r), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _mla_prefill_kernel, page_size=ps, n_pages=pages, t_chunk=t,
            heads=h, scale=sm_scale, quant=quant,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, h, r), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(flat_tbl, prefix_len, chunk_len, *operands)


# ====================================================== roofline estimates ==
def paged_kv_bytes_per_step(lengths, pages_per_slot: int, page_size: int,
                            row_bytes: int, impl: str) -> int:
    """Analytic KV bytes one decode step moves through HBM, per layer.

    ``row_bytes`` is the byte cost of one token row across every pool leaf
    (K+V, or ckv+kpe, plus scale rows for int8 pools).

    - ``"gather"``: the jnp path reads the full trash-padded table
      (``B × P × PS`` rows), writes the dense gathered copy, and re-reads it
      in the attention contraction → 3× full-table traffic, independent of
      how many rows are actually live.
    - ``"pallas"``: one streaming pass over live pages only
      (``Σ_b ceil(len_b / PS) × PS`` rows); dead-page DMAs are elided by the
      block-index clamp.
    """
    import numpy as np
    lengths = np.asarray(lengths)
    if impl == "gather":
        return int(3 * lengths.shape[0] * pages_per_slot * page_size * row_bytes)
    if impl == "pallas":
        live = -(-lengths // page_size) * page_size
        return int(live.sum() * row_bytes)
    raise ValueError(f"unknown impl {impl!r}")
