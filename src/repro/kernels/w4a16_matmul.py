"""W4A16/W4A8 group-wise dequant-inside-matmul Pallas TPU kernel.

TPU adaptation of the paper's LMDeploy-derived CUDA W4A16 GEMM (§2.3): int4
weights stay packed in HBM; each grid step DMAs one packed block into VMEM,
expands to bf16 *in VMEM*, and feeds the MXU.  HBM traffic for weights is ~¼
of bf16, which is the roofline win for memory-bound decode GEMMs.

The ``act="a8"`` body is the compute-bound *prefill* variant (FPTQ / arxiv
2311.05161): activations arrive pre-quantized to per-token symmetric int8
with their ``(bt, 1)`` scales riding along as a VMEM operand, the packed int4
block unpacks to zero-point-folded *int8 codes* instead of f32, and each grid
step contracts int8×int4→int32 on the MXU.  Weight scales differ per
quantization group (= per ``k`` step), so the int32 partial product is
rescaled by ``act_scale[bt,1] · weight_scale[1,bco]`` at each group boundary
into the f32 VMEM accumulator — the integer accumulation spans exactly one
group's contraction, which is the widest span over which a single rescale is
valid.

Layout contract (see ``repro.core.quantize``): packing is along the
contraction axis in group-split layout, so with ``block_ci == group_size`` a
weight block unpacks with a single sublane ``concat`` — no row interleave —
and uses exactly one ``scales``/``zeros`` row.

Grid: ``(T/bt, Co/bco, Ci/bci)`` with the contraction axis innermost; partial
products accumulate in an f32 VMEM scratch and are written back once per
``(i, j)`` tile on the last ``k`` step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import QuantizedTensor, quantize_acts_per_token
from repro.kernels import tpu_compiler_params

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_CO = 256


def _dequant_block(packed, scale, zero):
    """Expand one packed group-split weight block to f32 *in VMEM*: nibble
    split, sublane concat back to group order, then ``(codes − zero)·scale``.
    Shared by the 2-D and expert-grouped kernel bodies — the packing contract
    lives in exactly one place."""
    lo = (packed & 0x0F).astype(jnp.int8)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int8)
    codes = jnp.concatenate([lo, hi], axis=0)  # (bci, bco) group-split order
    return (codes.astype(jnp.float32) - zero.astype(jnp.float32)) * scale.astype(
        jnp.float32
    )


def _dequant_block_i8(packed, zero):
    """Expand one packed weight block to zero-point-folded *int8* codes.

    ``zeros`` are stored float-domain but integer-valued (``round`` in
    ``compute_qparams``); folding them keeps the block on the MXU's int8
    operand path.  Codes live in ``[0, 15]`` so ``codes − zero`` fits int8
    for any zero in ``[-112, 127]``; the clip guards pathological
    offset-only groups, mirrored exactly by the XLA oracle."""
    lo = (packed & 0x0F).astype(jnp.int32)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int32)
    codes = jnp.concatenate([lo, hi], axis=0)  # (bci, bco) group-split order
    z = jnp.round(zero.astype(jnp.float32)).astype(jnp.int32)
    return jnp.clip(codes - z, -128, 127).astype(jnp.int8)


def _kernel(x_ref, packed_ref, scales_ref, zeros_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # packed (bci//2, bco) uint8; scales/zeros (1, bco)
    w = _dequant_block(packed_ref[...], scales_ref[...], zeros_ref[...])
    x = x_ref[...].astype(jnp.float32)  # (bt, bci)
    acc_ref[...] += jax.lax.dot_general(
        x,
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_a8(
    x_ref, xs_ref, packed_ref, scales_ref, zeros_ref, o_ref, acc_ref, *, n_k
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # x (bt, bci) int8; xs (bt, 1) f32; packed (bci//2, bco) uint8
    wq = _dequant_block_i8(packed_ref[...], zeros_ref[...])
    part = jax.lax.dot_general(
        x_ref[...],
        wq,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    # per-(token, group) rescale: the int32 accumulation is only valid within
    # one quant group (weight scales change per k step), so the partial is
    # scaled into the f32 accumulator at each group boundary
    acc_ref[...] += (
        part.astype(jnp.float32)
        * scales_ref[...].astype(jnp.float32)
        * xs_ref[...]
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _fit_block_co(co: int, block_co: int) -> int:
    """Largest power-of-two-reduced divisor of ``co`` that is ≤ ``block_co``
    — ragged output widths shrink the block instead of raising or copying
    the packed weight into a padded buffer every call."""
    bco = min(block_co, co)
    while bco > 1 and co % bco:
        bco //= 2
    if co % bco:
        raise ValueError(f"Co={co} has no usable block ≤ {block_co}")
    return bco


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_co", "interpret", "act")
)
def w4a16_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    block_t: int = DEFAULT_BLOCK_T,
    block_co: int = DEFAULT_BLOCK_CO,
    interpret: bool = False,
    act: str = "a16",
) -> jax.Array:
    """``x[..., Ci] @ dequant(qt)[Ci, Co] -> [..., Co]`` via Pallas.

    The contraction block is pinned to the quantization group size so each
    grid step sees whole groups (one scales/zeros row per step).
    ``act="a8"`` quantizes ``x`` per token to symmetric int8 outside the
    kernel (one XLA pass) and runs the int8×int4→int32 body.
    """
    if qt.packed.ndim != 2:
        raise ValueError("pallas kernel handles 2-D weights; got leading dims")
    if act not in ("a16", "a8"):
        raise ValueError(f"act must be 'a16' or 'a8', got {act!r}")
    orig_shape = x.shape
    ci = orig_shape[-1]
    co = qt.packed.shape[1]
    group = qt.group_size
    if ci != qt.shape[0]:
        raise ValueError(f"x Ci={ci} != weight Ci={qt.shape[0]}")

    x2 = x.reshape(-1, ci)
    t = x2.shape[0]
    # decode-sized t (< block_t): bt pins to the 8-padded batch, so the token
    # dim is one grid step with no padding up to block_t, and the jit cache —
    # keyed on (shape, blocks) — makes steady-state decode compile exactly
    # once (asserted by test_decode_tiny_t_no_recompile)
    bt = min(block_t, _round_up(t, 8))
    bco = _fit_block_co(co, block_co)
    bci = group  # one quant group per contraction step

    if act == "a8":
        x2, xs = quantize_acts_per_token(x2)  # int8 codes, (t, 1) f32 scales

    t_pad = _round_up(t, bt)
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
        if act == "a8":
            xs = jnp.pad(xs, ((0, t_pad - t), (0, 0)))
    n_t, n_co, n_k = t_pad // bt, co // bco, ci // bci

    if act == "a8":
        kernel = functools.partial(_kernel_a8, n_k=n_k)
        operands = (x2, xs, qt.packed, qt.scales, qt.zeros)
        in_specs = [
            pl.BlockSpec((bt, bci), lambda i, j, k: (i, k)),
            pl.BlockSpec((bt, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bci // 2, bco), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bco), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bco), lambda i, j, k: (k, j)),
        ]
    else:
        kernel = functools.partial(_kernel, n_k=n_k)
        operands = (x2, qt.packed, qt.scales, qt.zeros)
        in_specs = [
            pl.BlockSpec((bt, bci), lambda i, j, k: (i, k)),
            pl.BlockSpec((bci // 2, bco), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bco), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bco), lambda i, j, k: (k, j)),
        ]

    out = pl.pallas_call(
        kernel,
        grid=(n_t, n_co, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, bco), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t_pad, co), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bco), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)

    if t_pad != t:
        out = out[:t]
    return out.reshape(*orig_shape[:-1], co)


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def vmem_bytes(block_t: int, block_co: int, group: int, dtype_bytes: int = 2) -> int:
    """Analytic VMEM working-set claim for one grid step (for roofline notes)."""
    x_blk = block_t * group * dtype_bytes
    w_blk = (group // 2) * block_co  # uint8
    sz = 2 * block_co * dtype_bytes  # scales+zeros rows
    acc = block_t * block_co * 4
    out = block_t * block_co * dtype_bytes
    return x_blk + w_blk + sz + acc + out
