"""Expert-batched grouped W4A16 Pallas TPU kernel.

Lifts the ``w4a16_matmul`` contract to *stacked* ``[E, Ci, Co]`` weights:
``x[E, C, D] @ dequant(qt)[E, D, F] -> [E, C, F]`` with the expert dim as the
outermost grid axis.  Each ``(e, i, j, k)`` grid step DMAs one packed block of
expert ``e`` into VMEM, expands to f32 *in VMEM* and feeds the MXU — int4 and
scales are the only weight bytes that ever cross HBM, which is the §2.3
roofline win applied per expert.  This is the serving path for MoE expert
FFNs (``models/mlp.py``, experts ride the grid) and for MLA's absorbed-form
decode projections (``models/attention.py``, heads ride the grid); both used
to re-inflate a dense f32 weight in HBM every step via ``dequantize``.

Layout contract is identical to ``w4a16_matmul``: group-split packing along
the contraction axis, the contraction block pinned to the quantization group
so each grid step unpacks with one sublane ``concat`` and uses exactly one
``scales``/``zeros`` row.  Zero-padded capacity rows (ragged MoE dispatch)
are harmless: a zero activation row contributes a zero output row regardless
of the asymmetric zero-points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import QuantizedTensor, quantize_acts_per_token
from repro.kernels import tpu_compiler_params
from repro.kernels.w4a16_matmul import (
    _dequant_block,
    _dequant_block_i8,
    _fit_block_co,
    _round_up,
)

DEFAULT_BLOCK_C = 256
DEFAULT_BLOCK_CO = 256


def _kernel(x_ref, packed_ref, scales_ref, zeros_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # this expert's block: packed (bci//2, bco) uint8; scales/zeros (1, bco)
    w = _dequant_block(packed_ref[0], scales_ref[0], zeros_ref[0])
    x = x_ref[0].astype(jnp.float32)  # (bc, bci)
    acc_ref[...] += jax.lax.dot_general(
        x,
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _kernel_a8(
    x_ref, xs_ref, packed_ref, scales_ref, zeros_ref, o_ref, acc_ref, *, n_k
):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # this expert's block: x (bc, bci) int8; xs (bc, 1) f32
    wq = _dequant_block_i8(packed_ref[0], zeros_ref[0])
    part = jax.lax.dot_general(
        x_ref[0],
        wq,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    # per-(row, group) rescale at each group boundary (see w4a16_matmul)
    acc_ref[...] += (
        part.astype(jnp.float32)
        * scales_ref[0].astype(jnp.float32)
        * xs_ref[0]
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_co", "interpret", "act")
)
def w4a16_grouped_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    block_c: int = DEFAULT_BLOCK_C,
    block_co: int = DEFAULT_BLOCK_CO,
    interpret: bool = False,
    act: str = "a16",
) -> jax.Array:
    """``x[E, C, D] @ dequant(qt)[E, D, F] -> [E, C, F]`` via Pallas.

    One grid cell touches one expert only, so sharding the expert axis (EP)
    shards the grid.  The contraction block is pinned to the quantization
    group size (whole groups per step, one scales/zeros row).  ``act="a8"``
    quantizes each ``(expert, row)`` to symmetric int8 outside the kernel and
    runs the int8×int4→int32 body; zero-padded capacity rows quantize to
    all-zero codes and still contribute zero output rows.
    """
    if qt.packed.ndim != 3:
        raise ValueError(
            f"grouped kernel needs stacked [E, Ci, Co] weights; got packed "
            f"shape {qt.packed.shape}")
    if x.ndim != 3:
        raise ValueError(f"expected x[E, C, D], got shape {x.shape}")
    if act not in ("a16", "a8"):
        raise ValueError(f"act must be 'a16' or 'a8', got {act!r}")
    e, c, d = x.shape
    if e != qt.packed.shape[0]:
        raise ValueError(f"x experts E={e} != weight experts {qt.packed.shape[0]}")
    if d != qt.shape[-2]:
        raise ValueError(f"x Ci={d} != weight Ci={qt.shape[-2]}")
    co = qt.packed.shape[-1]
    group = qt.group_size
    out_dtype = x.dtype

    if act == "a8":
        x, xs = quantize_acts_per_token(x)  # int8 codes, (e, c, 1) f32

    # decode-sized c (< block_c, e.g. MLA absorbed B rows per head): bc pins
    # to the 8-padded row count — one C-grid step, cached per shape
    bc = min(block_c, _round_up(c, 8))
    c_pad = _round_up(c, bc)
    if c_pad != c:
        x = jnp.pad(x, ((0, 0), (0, c_pad - c), (0, 0)))
        if act == "a8":
            xs = jnp.pad(xs, ((0, 0), (0, c_pad - c), (0, 0)))
    bco = _fit_block_co(co, block_co)
    n_c, n_co, n_k = c_pad // bc, co // bco, d // group

    if act == "a8":
        kernel = functools.partial(_kernel_a8, n_k=n_k)
        operands = (x, xs, qt.packed, qt.scales, qt.zeros)
        in_specs = [
            pl.BlockSpec((1, bc, group), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bc, 1), lambda e, i, j, k: (e, i, 0)),
            pl.BlockSpec((1, group // 2, bco), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, 1, bco), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, 1, bco), lambda e, i, j, k: (e, k, j)),
        ]
    else:
        kernel = functools.partial(_kernel, n_k=n_k)
        operands = (x, qt.packed, qt.scales, qt.zeros)
        in_specs = [
            pl.BlockSpec((1, bc, group), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, group // 2, bco), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, 1, bco), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, 1, bco), lambda e, i, j, k: (e, k, j)),
        ]

    out = pl.pallas_call(
        kernel,
        grid=(e, n_c, n_co, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bc, bco), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c_pad, co), out_dtype),
        scratch_shapes=[pltpu.VMEM((bc, bco), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)

    return out[:, :c] if c_pad != c else out


def grouped_weight_bytes(
    e: int, d: int, f: int, group: int, scale_bytes: int = 2
) -> tuple[int, int]:
    """(packed int4 + scales/zeros bytes, dense bf16 bytes) one full pass over
    the stacked weight moves through HBM — the ~4x roofline claim the
    ``w4a16_moe`` bench suite tracks."""
    packed = e * (d // 2) * f
    sz = 2 * e * (d // group) * f * scale_bytes
    return packed + sz, e * d * f * 2
