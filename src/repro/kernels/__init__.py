"""Pallas TPU kernels for the paper's compute hot-spots, with XLA references.

- ``w4a16_matmul``:     int4 weights dequantized in VMEM inside the GEMM (§2.3)
- ``w4a16_grouped``:    the same GEMM over stacked [E, Ci, Co] weights with the
                        expert/head dim on the grid (MoE experts, MLA absorbed)
- ``flash_attention``:  causal block-skipping online-softmax prefill attention
- ``paged_attention``:  decode attention with the KV page-table gather fused
                        into the kernel (scalar-prefetch block tables), fp16
                        and int8 pools

``ops.py`` is the dispatching entry point (pallas / interpret / xla);
``ref.py`` holds the pure-jnp oracles the interpret-mode tests compare
against.
"""
from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; take
# whichever this version ships.  Kernel modules import this instead of
# re-deriving it — it must be bound *before* the ops re-import below so the
# submodules' ``from repro.kernels import tpu_compiler_params`` resolves
# against the partially-initialised package.
_CompilerParams = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams"
)


def tpu_compiler_params(**kwargs):
    """Build the TPU ``compiler_params`` object for ``pl.pallas_call``."""
    return _CompilerParams(**kwargs)


from repro.kernels.ops import (  # noqa: E402,F401
    default_backend,
    gqa_paged_attention,
    mla_paged_attention,
    quantized_linear,
    w4a16_grouped_matmul,
    w4a16_matmul,
)
