"""Pure-jnp oracles for the kernels package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantizedTensor, dequantize


def w4a16_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Oracle: dequantize the whole weight, then a plain matmul.

    x: [..., Ci] activation (bf16/f32); qt: packed int4 weight [Ci, Co].
    Returns [..., Co] in x.dtype, accumulated in f32.
    """
    w = dequantize(qt, jnp.float32)
    y = jnp.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )
    return y.astype(x.dtype)


def w4a16_grouped_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Oracle for the expert-batched grouped kernel: dequantize the stacked
    ``[E, Ci, Co]`` weight, then a batched einsum.

    x: [E, C, Ci] per-expert activations; returns [E, C, Co] in x.dtype,
    accumulated in f32.  This is also the ``backend="xla"`` serving path on
    CPU hosts — XLA fuses the dequant into the contraction's producer.
    """
    w = dequantize(qt, jnp.float32)
    y = jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w,
        preferred_element_type=jnp.float32,
    )
    return y.astype(x.dtype)
