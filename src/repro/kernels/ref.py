"""Pure-jnp oracles for the kernels package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import (
    QuantizedTensor,
    dequantize,
    quantize_acts_per_token,
    unpack_codes,
)


def w4a16_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Oracle: dequantize the whole weight, then a plain matmul.

    x: [..., Ci] activation (bf16/f32); qt: packed int4 weight [Ci, Co].
    Returns [..., Co] in x.dtype, accumulated in f32.
    """
    w = dequantize(qt, jnp.float32)
    y = jnp.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )
    return y.astype(x.dtype)


def _folded_int_codes(qt: QuantizedTensor) -> jax.Array:
    """Zero-point-folded integer weight codes ``[..., G#, Gs, Co]`` (f32 but
    integer-valued), mirroring the kernels' ``_dequant_block_i8`` exactly —
    including its int8 clip for pathological offset-only groups."""
    q = unpack_codes(qt.packed, qt.group_size).astype(jnp.float32)
    *lead, ci, co = q.shape
    g = qt.scales.shape[-2]
    qg = q.reshape(*lead, g, ci // g, co)
    z = jnp.round(qt.zeros.astype(jnp.float32))
    return jnp.clip(qg - z[..., None, :], -128, 127)


def w4a8_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Oracle for the A8 kernel body: per-token symmetric int8 activations,
    integer contraction per quantization group, then the per-(token, group)
    rescale — the same association order as the Pallas kernel, so interpret
    vs XLA parity is tight.

    All integer arithmetic runs in f32: codes are ≤127·15 per product and
    group sums stay far below 2^24, so every intermediate is exact.
    """
    orig_shape = x.shape
    ci = orig_shape[-1]
    xq, xs = quantize_acts_per_token(x.reshape(-1, ci))
    wq = _folded_int_codes(qt)  # (G#, Gs, Co)
    g = wq.shape[-3]
    xg = xq.astype(jnp.float32).reshape(-1, g, ci // g)
    part = jnp.einsum(
        "tgi,gio->tgo", xg, wq, preferred_element_type=jnp.float32
    )
    y = jnp.sum(part * qt.scales.astype(jnp.float32)[None], axis=1) * xs
    return y.astype(x.dtype).reshape(*orig_shape[:-1], qt.packed.shape[-1])


def w4a16_grouped_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Oracle for the expert-batched grouped kernel: dequantize the stacked
    ``[E, Ci, Co]`` weight, then a batched einsum.

    x: [E, C, Ci] per-expert activations; returns [E, C, Co] in x.dtype,
    accumulated in f32.  This is also the ``backend="xla"`` serving path on
    CPU hosts — XLA fuses the dequant into the contraction's producer.
    """
    w = dequantize(qt, jnp.float32)
    y = jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w,
        preferred_element_type=jnp.float32,
    )
    return y.astype(x.dtype)


def w4a8_grouped_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """A8 oracle for the expert-batched grouped kernel: per-(expert, row)
    int8 activations, integer contraction per group, per-(row, group)
    rescale.  Zero-padded capacity rows quantize to all-zero codes and keep
    contributing zero output rows."""
    e, c, d = x.shape
    xq, xs = quantize_acts_per_token(x)  # int8 [E,C,D], f32 [E,C,1]
    wq = _folded_int_codes(qt)  # (E, G#, Gs, Co)
    g = wq.shape[-3]
    xg = xq.astype(jnp.float32).reshape(e, c, g, d // g)
    part = jnp.einsum(
        "ecgi,egio->ecgo", xg, wq, preferred_element_type=jnp.float32
    )
    y = jnp.sum(
        part * qt.scales.astype(jnp.float32)[:, None], axis=2
    ) * xs
    return y.astype(x.dtype)
