"""Jit'd public wrappers for kernels, with backend dispatch.

``backend``:
  - ``"pallas"``:    compiled Pallas TPU kernel (real TPU only).
  - ``"interpret"``: Pallas kernel body interpreted on CPU (tests).
  - ``"xla"``:       dequantize-then-matmul; XLA fuses the dequant into the
                     GEMM's producer.  Used for the CPU dry-run so lowering
                     succeeds on the host platform.
  - ``"auto"``:      pallas on TPU devices, xla otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantizedTensor
from repro.kernels import paged_attention as _pa
from repro.kernels import ref as _ref
from repro.kernels import w4a16_grouped as _w4g
from repro.kernels import w4a16_matmul as _w4


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# W4A8 token-count dispatch threshold: below this many rows the GEMM is
# memory-bound (decode batches, tail chunks) and W4A16 already wins — the
# int8 path only pays off when the MXU is the bottleneck.  Row counts are
# static at trace time (bucketed prefill chunks, fixed decode batch), so the
# choice of kernel body is a trace-time decision, not a runtime branch.
A8_MIN_TOKENS = 16


def _resolve_act(act: str, qt: QuantizedTensor, rows: int) -> str:
    """Gate the A8 request: the caller asks (``act="a8"``), the calibration
    verdict rides on the tensor (``qt.a8`` — per-layer fallback), and the
    static row count keeps small-T decode on the A16 body."""
    if act not in ("a16", "a8"):
        raise ValueError(f"act must be 'a16' or 'a8', got {act!r}")
    if act == "a8" and qt.a8 and rows >= A8_MIN_TOKENS:
        return "a8"
    return "a16"


def w4a16_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    backend: str = "auto",
    act: str = "a16",
    block_t: int = _w4.DEFAULT_BLOCK_T,
    block_co: int = _w4.DEFAULT_BLOCK_CO,
) -> jax.Array:
    """Quantized linear contraction ``x @ dequant(qt)``.

    ``act="a8"`` requests the W4A8 prefill body (per-token int8 activations,
    int8×int4→int32 MXU contraction); it is honored only when the tensor's
    calibration-derived ``a8`` flag is set and the flattened token count
    reaches :data:`A8_MIN_TOKENS` — otherwise the call falls back to the
    untouched A16 path."""
    if backend == "auto":
        backend = default_backend()
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    act = _resolve_act(act, qt, rows)
    if backend == "pallas":
        return _w4.w4a16_matmul(
            x, qt, block_t=block_t, block_co=block_co, act=act)
    if backend == "interpret":
        return _w4.w4a16_matmul(
            x, qt, block_t=block_t, block_co=block_co, interpret=True, act=act
        )
    if backend == "xla":
        if act == "a8":
            return _ref.w4a8_matmul_ref(x, qt)
        return _ref.w4a16_matmul_ref(x, qt)
    raise ValueError(f"unknown backend {backend!r}")


def w4a16_grouped_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    backend: str = "auto",
    act: str = "a16",
    block_c: int = _w4g.DEFAULT_BLOCK_C,
    block_co: int = _w4g.DEFAULT_BLOCK_CO,
) -> jax.Array:
    """Expert-batched quantized contraction ``x[E,C,D] @ dequant(qt)[E,D,F]``.

    The serving entry for stacked ``[E, Ci, Co]`` weights (MoE experts, MLA
    absorbed-form heads): packed int4 + scales are the only resident weight
    format on every backend — the XLA path dequantizes inside the fused
    contraction, never as a persisted dense copy.  ``act="a8"`` follows the
    same gating as :func:`w4a16_matmul` with the per-expert row count ``C``
    as the token count (MLA absorbed decode runs C = batch rows and stays
    A16)."""
    if backend == "auto":
        backend = default_backend()
    act = _resolve_act(act, qt, x.shape[1])
    if backend == "pallas":
        return _w4g.w4a16_grouped_matmul(
            x, qt, block_c=block_c, block_co=block_co, act=act)
    if backend == "interpret":
        return _w4g.w4a16_grouped_matmul(
            x, qt, block_c=block_c, block_co=block_co, interpret=True,
            act=act)
    if backend == "xla":
        if act == "a8":
            return _ref.w4a8_grouped_ref(x, qt)
        return _ref.w4a16_grouped_ref(x, qt)
    raise ValueError(f"unknown backend {backend!r}")


def quantized_linear(
    x: jax.Array,
    qt: QuantizedTensor,
    bias: jax.Array | None = None,
    *,
    backend: str = "auto",
) -> jax.Array:
    y = w4a16_matmul(x, qt, backend=backend)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def gqa_paged_attention(q, k_pool, v_pool, table_rows, lengths,
                        k_scale=None, v_scale=None, *, sm_scale: float,
                        backend: str = "auto") -> jax.Array:
    """Fused page-table-gather decode attention (GQA).  The jnp gather
    reference lives model-side (``models.attention.gqa_decode_paged`` with
    ``paged_attn_impl="gather"``) — this entry only dispatches the kernel."""
    if backend == "auto":
        backend = default_backend()
    if backend not in ("pallas", "interpret"):
        raise ValueError(
            f"paged attention kernel backend must be pallas/interpret, got "
            f"{backend!r}; use the model-level gather path for XLA")
    return _pa.gqa_paged_attention(
        q, k_pool, v_pool, table_rows, lengths, k_scale, v_scale,
        sm_scale=sm_scale, interpret=(backend == "interpret"))


def mla_paged_attention(q_lat, q_pe, ckv_pool, kpe_pool, table_rows, lengths,
                        ckv_scale=None, kpe_scale=None, *, sm_scale: float,
                        backend: str = "auto") -> jax.Array:
    """Fused page-table-gather decode attention (MLA absorbed form)."""
    if backend == "auto":
        backend = default_backend()
    if backend not in ("pallas", "interpret"):
        raise ValueError(
            f"paged attention kernel backend must be pallas/interpret, got "
            f"{backend!r}; use the model-level gather path for XLA")
    return _pa.mla_paged_attention(
        q_lat, q_pe, ckv_pool, kpe_pool, table_rows, lengths,
        ckv_scale, kpe_scale,
        sm_scale=sm_scale, interpret=(backend == "interpret"))


def gqa_paged_prefill(q, k_suf, v_suf, k_pool, v_pool, table_rows,
                      prefix_len, chunk_len, k_scale=None, v_scale=None, *,
                      sm_scale: float, backend: str = "auto") -> jax.Array:
    """Chunked-prefill attention off the paged pools (GQA).  The jnp gather
    oracle lives model-side (``models.attention.gqa_prefill_chunk`` with
    ``paged_attn_impl="gather"``)."""
    if backend == "auto":
        backend = default_backend()
    if backend not in ("pallas", "interpret"):
        raise ValueError(
            f"paged prefill kernel backend must be pallas/interpret, got "
            f"{backend!r}; use the model-level gather path for XLA")
    return _pa.gqa_paged_prefill(
        q, k_suf, v_suf, k_pool, v_pool, table_rows, prefix_len, chunk_len,
        k_scale, v_scale,
        sm_scale=sm_scale, interpret=(backend == "interpret"))


def mla_paged_prefill(q_lat, q_pe, ckv_suf, kpe_suf, ckv_pool, kpe_pool,
                      table_rows, prefix_len, chunk_len,
                      ckv_scale=None, kpe_scale=None, *, sm_scale: float,
                      backend: str = "auto") -> jax.Array:
    """Chunked-prefill attention off the paged pools (MLA absorbed form)."""
    if backend == "auto":
        backend = default_backend()
    if backend not in ("pallas", "interpret"):
        raise ValueError(
            f"paged prefill kernel backend must be pallas/interpret, got "
            f"{backend!r}; use the model-level gather path for XLA")
    return _pa.mla_paged_prefill(
        q_lat, q_pe, ckv_suf, kpe_suf, ckv_pool, kpe_pool, table_rows,
        prefix_len, chunk_len, ckv_scale, kpe_scale,
        sm_scale=sm_scale, interpret=(backend == "interpret"))
