"""Jit'd public wrappers for kernels, with backend dispatch.

``backend``:
  - ``"pallas"``:    compiled Pallas TPU kernel (real TPU only).
  - ``"interpret"``: Pallas kernel body interpreted on CPU (tests).
  - ``"xla"``:       dequantize-then-matmul; XLA fuses the dequant into the
                     GEMM's producer.  Used for the CPU dry-run so lowering
                     succeeds on the host platform.
  - ``"auto"``:      pallas on TPU devices, xla otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantizedTensor
from repro.kernels import paged_attention as _pa
from repro.kernels import ref as _ref
from repro.kernels import w4a16_grouped as _w4g
from repro.kernels import w4a16_matmul as _w4


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def w4a16_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    backend: str = "auto",
    block_t: int = _w4.DEFAULT_BLOCK_T,
    block_co: int = _w4.DEFAULT_BLOCK_CO,
) -> jax.Array:
    """Quantized linear contraction ``x @ dequant(qt)``."""
    if backend == "auto":
        backend = default_backend()
    if backend == "pallas":
        return _w4.w4a16_matmul(x, qt, block_t=block_t, block_co=block_co)
    if backend == "interpret":
        return _w4.w4a16_matmul(
            x, qt, block_t=block_t, block_co=block_co, interpret=True
        )
    if backend == "xla":
        return _ref.w4a16_matmul_ref(x, qt)
    raise ValueError(f"unknown backend {backend!r}")


def w4a16_grouped_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    backend: str = "auto",
    block_c: int = _w4g.DEFAULT_BLOCK_C,
    block_co: int = _w4g.DEFAULT_BLOCK_CO,
) -> jax.Array:
    """Expert-batched quantized contraction ``x[E,C,D] @ dequant(qt)[E,D,F]``.

    The serving entry for stacked ``[E, Ci, Co]`` weights (MoE experts, MLA
    absorbed-form heads): packed int4 + scales are the only resident weight
    format on every backend — the XLA path dequantizes inside the fused
    contraction, never as a persisted dense copy."""
    if backend == "auto":
        backend = default_backend()
    if backend == "pallas":
        return _w4g.w4a16_grouped_matmul(
            x, qt, block_c=block_c, block_co=block_co)
    if backend == "interpret":
        return _w4g.w4a16_grouped_matmul(
            x, qt, block_c=block_c, block_co=block_co, interpret=True)
    if backend == "xla":
        return _ref.w4a16_grouped_ref(x, qt)
    raise ValueError(f"unknown backend {backend!r}")


def quantized_linear(
    x: jax.Array,
    qt: QuantizedTensor,
    bias: jax.Array | None = None,
    *,
    backend: str = "auto",
) -> jax.Array:
    y = w4a16_matmul(x, qt, backend=backend)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def gqa_paged_attention(q, k_pool, v_pool, table_rows, lengths,
                        k_scale=None, v_scale=None, *, sm_scale: float,
                        backend: str = "auto") -> jax.Array:
    """Fused page-table-gather decode attention (GQA).  The jnp gather
    reference lives model-side (``models.attention.gqa_decode_paged`` with
    ``paged_attn_impl="gather"``) — this entry only dispatches the kernel."""
    if backend == "auto":
        backend = default_backend()
    if backend not in ("pallas", "interpret"):
        raise ValueError(
            f"paged attention kernel backend must be pallas/interpret, got "
            f"{backend!r}; use the model-level gather path for XLA")
    return _pa.gqa_paged_attention(
        q, k_pool, v_pool, table_rows, lengths, k_scale, v_scale,
        sm_scale=sm_scale, interpret=(backend == "interpret"))


def mla_paged_attention(q_lat, q_pe, ckv_pool, kpe_pool, table_rows, lengths,
                        ckv_scale=None, kpe_scale=None, *, sm_scale: float,
                        backend: str = "auto") -> jax.Array:
    """Fused page-table-gather decode attention (MLA absorbed form)."""
    if backend == "auto":
        backend = default_backend()
    if backend not in ("pallas", "interpret"):
        raise ValueError(
            f"paged attention kernel backend must be pallas/interpret, got "
            f"{backend!r}; use the model-level gather path for XLA")
    return _pa.mla_paged_attention(
        q_lat, q_pe, ckv_pool, kpe_pool, table_rows, lengths,
        ckv_scale, kpe_scale,
        sm_scale=sm_scale, interpret=(backend == "interpret"))


def gqa_paged_prefill(q, k_suf, v_suf, k_pool, v_pool, table_rows,
                      prefix_len, chunk_len, k_scale=None, v_scale=None, *,
                      sm_scale: float, backend: str = "auto") -> jax.Array:
    """Chunked-prefill attention off the paged pools (GQA).  The jnp gather
    oracle lives model-side (``models.attention.gqa_prefill_chunk`` with
    ``paged_attn_impl="gather"``)."""
    if backend == "auto":
        backend = default_backend()
    if backend not in ("pallas", "interpret"):
        raise ValueError(
            f"paged prefill kernel backend must be pallas/interpret, got "
            f"{backend!r}; use the model-level gather path for XLA")
    return _pa.gqa_paged_prefill(
        q, k_suf, v_suf, k_pool, v_pool, table_rows, prefix_len, chunk_len,
        k_scale, v_scale,
        sm_scale=sm_scale, interpret=(backend == "interpret"))


def mla_paged_prefill(q_lat, q_pe, ckv_suf, kpe_suf, ckv_pool, kpe_pool,
                      table_rows, prefix_len, chunk_len,
                      ckv_scale=None, kpe_scale=None, *, sm_scale: float,
                      backend: str = "auto") -> jax.Array:
    """Chunked-prefill attention off the paged pools (MLA absorbed form)."""
    if backend == "auto":
        backend = default_backend()
    if backend not in ("pallas", "interpret"):
        raise ValueError(
            f"paged prefill kernel backend must be pallas/interpret, got "
            f"{backend!r}; use the model-level gather path for XLA")
    return _pa.mla_paged_prefill(
        q_lat, q_pe, ckv_suf, kpe_suf, ckv_pool, kpe_pool, table_rows,
        prefix_len, chunk_len, ckv_scale, kpe_scale,
        sm_scale=sm_scale, interpret=(backend == "interpret"))
