"""Code Llama-13B (paper Table 1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codellama-13b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=40, head_dim=128, d_ff=13824, vocab_size=32016,
    rope="standard", rope_theta=1e6, mlp="swiglu",
)

SMOKE_CONFIG = ModelConfig(
    name="codellama-13b-smoke", family="dense", num_layers=6, d_model=160,
    num_heads=5, num_kv_heads=5, head_dim=32, d_ff=320, vocab_size=512,
    rope="standard", rope_theta=1e6, mlp="swiglu",
)
