"""RWKV6-7B (Finch) — attention-free, data-dependent decay.  [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", num_layers=32, d_model=4096,
    num_heads=64, num_kv_heads=64, d_ff=14336, vocab_size=65536,
    mixer="rwkv6", rope="none", norm="layernorm", ssm_head_dim=64,
    subquadratic=True,
)

SMOKE_CONFIG = ModelConfig(
    name="rwkv6-7b-smoke", family="ssm", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
    mixer="rwkv6", rope="none", norm="layernorm", ssm_head_dim=16,
    subquadratic=True,
)
