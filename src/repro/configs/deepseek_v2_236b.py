"""DeepSeek-V2-236B — MLA (kv_lora=512) + MoE 160e top-6, 2 shared experts.
[arXiv:2405.04434; hf]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", num_layers=60, d_model=5120,
    num_heads=128, num_kv_heads=128, d_ff=1536, vocab_size=102400,
    mixer="mla", rope="standard", mlp="swiglu",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536, num_shared_experts=2),
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-v2-236b-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=32, vocab_size=256,
    mixer="mla", rope="standard", mlp="swiglu",
    mla=MLAConfig(kv_lora_rank=16, q_lora_rank=32, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=32, num_shared_experts=1,
                  capacity_factor=4.0),
)
