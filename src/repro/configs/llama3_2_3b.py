"""Llama-3.2-3B (small Llama3).  [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense", num_layers=28, d_model=3072,
    num_heads=24, num_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=128256,
    rope="standard", rope_theta=5e5, mlp="swiglu", tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="llama3.2-3b-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    rope="standard", mlp="swiglu", tie_embeddings=True,
)
