"""Granite-3.0-1B-A400M — 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=8, head_dim=64, d_ff=512, vocab_size=49155,
    rope="standard", mlp="swiglu", tie_embeddings=True,
    moe=MoEConfig(num_experts=32, top_k=8, d_expert=512),
)

SMOKE_CONFIG = ModelConfig(
    name="granite-moe-1b-a400m-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=32, vocab_size=256,
    rope="standard", mlp="swiglu", tie_embeddings=True,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=32, capacity_factor=4.0),
)
