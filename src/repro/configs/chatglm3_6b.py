"""ChatGLM3-6B — RoPE-2d, GQA kv=2.  [arXiv:2406.12793; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense", num_layers=28, d_model=4096,
    num_heads=32, num_kv_heads=2, head_dim=128, d_ff=13696, vocab_size=65024,
    rope="2d", mlp="swiglu",
)

SMOKE_CONFIG = ModelConfig(
    name="chatglm3-6b-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    rope="2d", mlp="swiglu",
)
