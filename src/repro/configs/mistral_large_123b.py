"""Mistral-Large-Instruct-2407 (123B dense).  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense", num_layers=88, d_model=12288,
    num_heads=96, num_kv_heads=8, head_dim=128, d_ff=28672, vocab_size=32768,
    rope="standard", rope_theta=1e6, mlp="swiglu",
)

SMOKE_CONFIG = ModelConfig(
    name="mistral-large-123b-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    rope="standard", mlp="swiglu",
)
