"""Zamba2-7B — Mamba2 backbone + weight-shared attention every 6 layers.
[arXiv:2411.15242; unverified]"""
from repro.configs.base import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
    num_heads=32, num_kv_heads=32, head_dim=112, d_ff=14336, vocab_size=32000,
    mixer="mamba2", ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    hybrid=HybridConfig(attn_every=6), subquadratic=True,
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-7b-smoke", family="hybrid", num_layers=5, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
    mixer="mamba2", ssm_state=16, ssm_head_dim=16, ssm_expand=2,
    hybrid=HybridConfig(attn_every=2), subquadratic=True,
)
