"""Code Llama-7B — the paper's primary eval model (Llama2 arch)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codellama-7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, head_dim=128, d_ff=11008, vocab_size=32016,
    rope="standard", rope_theta=1e6, mlp="swiglu",
)

SMOKE_CONFIG = ModelConfig(
    name="codellama-7b-smoke", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    rope="standard", rope_theta=1e6, mlp="swiglu",
)
