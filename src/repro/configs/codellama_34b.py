"""Code Llama-34B — the paper's headline deployment target (GQA kv=8)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codellama-34b", family="dense", num_layers=48, d_model=8192,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=22016, vocab_size=32016,
    rope="standard", rope_theta=1e6, mlp="swiglu",
)

SMOKE_CONFIG = ModelConfig(
    name="codellama-34b-smoke", family="dense", num_layers=8, d_model=192,
    num_heads=6, num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512,
    rope="standard", rope_theta=1e6, mlp="swiglu",
)
