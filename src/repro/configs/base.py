"""Model / run configuration dataclasses.

Every assigned architecture gets a module in ``repro/configs/<id>.py`` that
exports ``CONFIG`` (exact, full-size — used only by the dry-run, never
allocated) and ``SMOKE_CONFIG`` (same family, tiny — used by CPU tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden dim
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: mamba2 backbone + weight-shared attention block."""
    attn_every: int = 6                # shared attn after every N mamba layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # default d_model // num_heads
    mixer: str = "attention"           # attention | mla | mamba2 | rwkv6
    mlp: str = "swiglu"                # swiglu | gelu
    rope: str = "standard"             # standard | 2d | mrope | none
    rope_theta: float = 1e4
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    hybrid: Optional[HybridConfig] = None
    ssm_state: int = 0                 # mamba2 state size N
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    encdec: bool = False               # whisper
    enc_layers: int = 0
    tie_embeddings: bool = False
    attn_bias: bool = False            # starcoder2/whisper use biases
    dtype: str = "bfloat16"
    # long-context support marker (sub-quadratic mixer): set per-arch
    subquadratic: bool = False
    # beyond-paper: int8 KV cache (decode path) — halves cache HBM traffic
    kv_quant: bool = False
    # attention impl for full-sequence paths: "chunked" (jnp online-softmax,
    # CPU/dry-run lowerable) | "flash" (Pallas kernel w/ causal block-skip,
    # real-TPU; interpret-mode in tests)
    attn_impl: str = "chunked"
    # paged decode attention impl: "auto" (Pallas kernel when the backend is
    # pallas / a TPU, jnp gather otherwise) | "gather" (jnp page gather — the
    # XLA reference and oracle) | "pallas" (fused page-table-DMA kernel,
    # real-TPU) | "pallas_interpret" (same kernel interpreted on CPU, tests)
    paged_attn_impl: str = "auto"
    # activation quantization: "a16" (bf16/f32 activations everywhere — the
    # default, token-identical to the pre-W4A8 engine) | "a8_prefill"
    # (prefill-chunk GEMMs quantize activations per-token to int8 and run the
    # int8×int4→int32 kernel body on A8-eligible layers; decode GEMMs stay
    # A16 via the token-count gate in kernels.ops)
    act_quant: str = "a16"

    @property
    def hdim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def act_kernel(self) -> str:
        """The per-call ``act=`` value model code hands to ``kernels.ops`` —
        the ops-level gate (per-tensor eligibility flag + token count)
        decides whether the A8 body actually runs."""
        return "a8" if self.act_quant == "a8_prefill" else "a16"

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str                          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    enabled: bool = True
    group_size: int = 128
    # layers excluded from quantization (paper keeps embeddings/norms fp16;
    # we also keep lm_head and MoE routers in bf16, matching common practice)
    skip_lm_head: bool = True
    skip_router: bool = True
    alpha: Optional[float] = None      # None → use searched value
    backend: str = "auto"              # kernels.ops backend
    # W4A8 eligibility: layers whose worst per-token int8 activation
    # round-trip error (post-smoothing, on the calibration set) exceeds this
    # fall back to A16 in the prefill path.  Gaussian-ish rows score
    # ~1/(127·√12) ≈ 0.7–0.9%; rows still dominated by surviving outlier
    # channels score 2%+.  Part of the PTQ artifact fingerprint, so changing
    # it invalidates saved artifacts.
    a8_threshold: float = 0.015


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatch: Optional[int] = None   # per-device microbatching (grad accum)
    remat: str = "block"               # none | block | full
    zero_sharded_optimizer: bool = True
    grad_compression: str = "none"     # none | int8_ef
