"""Config registry: assigned architectures ↔ modules.

Each module exports ``CONFIG`` (exact full-size, dry-run only) and
``SMOKE_CONFIG`` (same family, tiny, CPU-runnable).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    HybridConfig, MLAConfig, ModelConfig, MoEConfig, QuantConfig, ShapeConfig,
    SHAPES, SHAPES_BY_NAME, TrainConfig,
)

ARCH_IDS = (
    "mistral-large-123b",
    "chatglm3-6b",
    "llama3.2-3b",
    "starcoder2-15b",
    "zamba2-7b",
    "qwen2-vl-7b",
    "granite-moe-1b-a400m",
    "deepseek-v2-236b",
    "rwkv6-7b",
    "whisper-medium",
    # paper's own evaluation family
    "codellama-7b",
    "codellama-13b",
    "codellama-34b",
)

def _modname(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_modname(arch_id)}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG
