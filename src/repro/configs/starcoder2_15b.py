"""StarCoder2-15B — GQA kv=4, LayerNorm + biases, GELU MLP.  [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense", num_layers=40, d_model=6144,
    num_heads=48, num_kv_heads=4, head_dim=128, d_ff=24576, vocab_size=49152,
    rope="standard", rope_theta=1e5, mlp="gelu", norm="layernorm", attn_bias=True,
)

SMOKE_CONFIG = ModelConfig(
    name="starcoder2-15b-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    rope="standard", mlp="gelu", norm="layernorm", attn_bias=True,
)
