"""Qwen2-VL-7B — M-RoPE; vision frontend stubbed (patch embeds precomputed).
[arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, head_dim=128, d_ff=18944, vocab_size=152064,
    rope="mrope", rope_theta=1e6, mlp="swiglu", attn_bias=True,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-7b-smoke", family="vlm", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    rope="mrope", mlp="swiglu", attn_bias=True,
)
