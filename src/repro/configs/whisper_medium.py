"""Whisper-medium — enc-dec, conv frontend stubbed.  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio", num_layers=24, enc_layers=24,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64, d_ff=4096,
    vocab_size=51865, rope="none", norm="layernorm", mlp="gelu",
    attn_bias=True, encdec=True, tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-medium-smoke", family="audio", num_layers=2, enc_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=256, rope="none", norm="layernorm", mlp="gelu",
    attn_bias=True, encdec=True, tie_embeddings=True,
)
