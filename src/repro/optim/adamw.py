"""AdamW with cosine/linear schedules, global-norm clipping, and optional
int8 gradient compression with error feedback (for the low-bandwidth pod
axis).  No optax dependency — pure pytree transforms, so optimizer state
shards under the same GSPMD rules as params (ZeRO: see sharding.rules)."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


@dataclasses.dataclass(frozen=True)
class OptState:
    step: jax.Array          # i32 scalar
    mu: Any                  # f32 tree
    nu: Any                  # f32 tree
    ef: Optional[Any] = None # error-feedback residual (grad compression)


jax.tree_util.register_dataclass(
    OptState, data_fields=["step", "mu", "nu", "ef"], meta_fields=[]
)


def init_opt_state(params, tc: TrainConfig) -> OptState:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
    ef = zeros(params) if tc.grad_compression == "int8_ef" else None
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                    nu=zeros(params), ef=ef)


def lr_schedule(tc: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - tc.warmup_steps) / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


# ------------------------------------------------- int8 grad compression ----
def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g)) + 1e-12
    q = jnp.clip(jnp.round(g / amax * 127.0), -127, 127).astype(jnp.int8)
    return q, amax


def decompress_int8(q: jax.Array, amax: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * (amax / 127.0)


def compress_grads_with_ef(grads, ef):
    """Error-feedback int8 compression: residual carries quantization error
    into the next step, so the compressed all-reduce stays unbiased in the
    long run (1-bit-Adam-style).  Returns (decompressed grads, new residual).

    Under pjit the compression happens BEFORE the psum that GSPMD inserts for
    data-parallel grad reduction, cutting pod-link bytes ~4×."""
    def one(g, e):
        v = g.astype(jnp.float32) + e
        q, amax = compress_int8(v)
        d = decompress_int8(q, amax)
        return d, v - d

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def adamw_update(params, grads, st: OptState, tc: TrainConfig):
    """One AdamW step.  Returns (params, new_state, metrics)."""
    if tc.grad_compression == "int8_ef" and st.ef is not None:
        grads, new_ef = compress_grads_with_ef(grads, st.ef)
    else:
        new_ef = st.ef
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    step = st.step + 1
    lr = lr_schedule(tc, step)
    b1, b2, eps, wd = tc.beta1, tc.beta2, tc.eps, tc.weight_decay
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(st.mu)
    flat_v = treedef.flatten_up_to(st.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v, new_ef), {"grad_norm": gnorm, "lr": lr}
