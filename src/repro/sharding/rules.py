"""Logical-axis sharding rules: param/batch/cache PartitionSpec trees.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Pods are pure data-parallel (lowest pressure on the slower
inter-pod links); "model" carries TP/EP.

Parallelism mapping (see DESIGN.md §5):
  TP   attention heads / FFN hidden / per-head SSM channels → "model"
  EP   MoE experts → "model" (sort-based dispatch shards the [E, C, D] bufs)
  DP   batch → ("pod", "data")
  SP   decode KV caches: sequence axis → "model" (+ "data" when batch==1,
       the long-context cell) — softmax over a sharded axis lowers to a
       max/sum all-reduce pair, the GSPMD flash-decode pattern
  ZeRO optimizer state: extra "data" sharding over the largest divisible dim

Rules are matched by parameter path suffix.  Quantized weights (packed /
scales / zeros) inherit the fp weight's spec; scales/zeros drop only the
group-axis (Ci/G) sharding — rarely divisible — and keep the lead axes
(stacked experts → EP, absorbed MLA heads → TP) and the output axis, so the
packed/scales/zeros trio is co-sharded everywhere it counts.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

DATA = "data"
MODEL = "model"


def batch_axes(mesh) -> Tuple[str, ...]:
    return ("pod", DATA) if "pod" in mesh.axis_names else (DATA,)


def _path_str(path) -> str:
    toks = []
    for k in path:
        if hasattr(k, "key"):
            toks.append(str(k.key))
        elif hasattr(k, "name"):
            toks.append(str(k.name))
        else:
            toks.append(str(getattr(k, "idx", k)))
    return "/".join(toks)


# (suffix, base spec for the LAST ndim dims of an fp weight)
# order matters: first match wins
_RULES = (
    ("embed/table", P(MODEL, None)),
    ("lm_head/w", P(None, MODEL)),
    # attention (+ rwkv time-mix shares the names)
    ("mixer/wq/w", P(None, MODEL)), ("mixer/wk/w", P(None, MODEL)),
    ("mixer/wv/w", P(None, MODEL)), ("mixer/wg/w", P(None, MODEL)),
    ("mixer/wo/w", P(MODEL, None)),
    ("self_attn/wq/w", P(None, MODEL)), ("self_attn/wk/w", P(None, MODEL)),
    ("self_attn/wv/w", P(None, MODEL)), ("self_attn/wo/w", P(MODEL, None)),
    ("cross_attn/wq/w", P(None, MODEL)), ("cross_attn/wk/w", P(None, MODEL)),
    ("cross_attn/wv/w", P(None, MODEL)), ("cross_attn/wo/w", P(MODEL, None)),
    ("mixer/wq/b", P(MODEL)), ("mixer/wk/b", P(MODEL)), ("mixer/wv/b", P(MODEL)),
    ("self_attn/wq/b", P(MODEL)), ("self_attn/wk/b", P(MODEL)), ("self_attn/wv/b", P(MODEL)),
    ("cross_attn/wq/b", P(MODEL)), ("cross_attn/wk/b", P(MODEL)), ("cross_attn/wv/b", P(MODEL)),
    ("wo/b", P(None)),
    # MLA
    ("mixer/wq_a/w", P(None, None)), ("mixer/wkv_a/w", P(None, None)),
    ("mixer/wq_b/w", P(None, MODEL)), ("mixer/wkv_b/w", P(None, MODEL)),
    # MLA absorbed-form decode weights (stacked int4 [H, Ci, Co]; heads ride
    # the lead axis → TP, contraction/group axes stay unsharded)
    ("wkv_b_absorbed/wk_t", P(MODEL, None, None)),
    ("wkv_b_absorbed/wv", P(MODEL, None, None)),
    # MoE
    ("experts/gate", P(MODEL, None, None)), ("experts/up", P(MODEL, None, None)),
    ("experts/down", P(MODEL, None, None)),
    ("router/w", P(None, None)),
    # dense MLP / shared expert
    ("mlp/gate/w", P(None, MODEL)), ("mlp/up/w", P(None, MODEL)),
    ("mlp/down/w", P(MODEL, None)),
    ("shared/gate/w", P(None, MODEL)), ("shared/up/w", P(None, MODEL)),
    ("shared/down/w", P(MODEL, None)),
    ("gate/b", P(MODEL)), ("up/b", P(MODEL)), ("down/b", P(None)),
    # rwkv channel mix (under mlp/)
    ("mlp/wk/w", P(None, MODEL)), ("mlp/wv/w", P(MODEL, None)),
    ("mlp/wr/w", P(None, MODEL)),
    # mamba2
    ("mixer/in_z/w", P(None, MODEL)), ("mixer/in_x/w", P(None, MODEL)),
    ("mixer/in_bc/w", P(None, None)), ("mixer/in_dt/w", P(None, MODEL)),
    ("conv_x_w", P(None, MODEL)), ("conv_x_b", P(MODEL)),
    ("conv_bc_w", P(None, None)), ("conv_bc_b", P(None)),
    ("dt_bias", P(MODEL)), ("a_log", P(MODEL)), ("d_skip", P(MODEL)),
    ("mixer/norm/scale", P(MODEL)),
    ("mixer/out_proj/w", P(MODEL, None)),
    # rwkv specific
    ("w_lora_a", P(None, None)), ("w_lora_b", P(None, MODEL)),
    ("w0", P(MODEL)), ("u_bonus", P(MODEL)),
    ("ln_x/scale", P(MODEL)), ("ln_x/bias", P(MODEL)),
    ("mixer/mix", P(None, None)), ("mlp/mix", P(None, None)),
)


def _match(ps: str) -> Optional[P]:
    for suffix, spec in _RULES:
        if ps.endswith(suffix):
            return spec
    return None


def _pad_lead(spec: P, ndim: int, qfield: Optional[str] = None) -> P:
    """Prepend None for stacked layer dims; adapt for quantized fields.

    ``packed`` keeps the fp weight's spec verbatim (its row dim is Ci/2 —
    divisibility is re-checked against the real leaf shape).  ``scales`` /
    ``zeros`` drop only the *group-axis* (second-to-last) sharding, which is
    rarely divisible, and keep every lead axis (layer stack / MoE expert /
    MLA head → EP/TP) plus the output axis — so the packed/scales/zeros trio
    stays co-sharded on every axis that matters."""
    base = tuple(spec)
    if qfield in ("scales", "zeros") and len(base) >= 2:
        base = (*base[:-2], None, base[-1])
    lead = ndim - len(base)
    if lead < 0:  # spec longer than leaf ndim (e.g. bias under moe) — trim
        base = base[-ndim:]
        lead = 0
    return P(*([None] * lead + list(base)))


def _divisible(shape, spec: P, mesh) -> bool:
    sizes = dict(mesh.shape)
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        need = int(np.prod([sizes[a] for a in axs]))
        if dim % need != 0:
            return False
    return True


_KV_NAMES = ("wk/w", "wv/w", "wk/b", "wv/b")


def param_specs(params_shape, mesh, cfg: Optional[ModelConfig] = None) -> Any:
    """PartitionSpec tree for a param (shape/val) tree.

    Falls back to replication when a matched spec doesn't divide the dims.
    KV projections are REPLICATED when num_kv_heads doesn't divide the model
    axis: col-sharding them would split head_dim across devices and put a
    giant score all-reduce inside every attention layer (MaxText does the
    same for small-KV GQA under wide TP).  RWKV's "wk/wv" share the names but
    are attention-free — their columns are per-head channels, so the rule
    only fires for attention mixers.
    """
    sizes = dict(mesh.shape)
    repl_kv = (
        cfg is not None
        and cfg.mixer in ("attention", "mla")
        and cfg.num_kv_heads % sizes[MODEL] != 0
    )

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        ndim = len(leaf.shape)
        qfield = None
        if ps.endswith("/packed") or ps.endswith("/scales") or ps.endswith("/zeros"):
            qfield = ps.rsplit("/", 1)[1]
            ps = ps.rsplit("/", 1)[0]
        if repl_kv and any(ps.endswith(k) for k in _KV_NAMES) and "mlp/" not in ps:
            return P()
        spec = _match(ps)
        if spec is None:
            return P()  # norms, small vectors → replicated
        spec = _pad_lead(spec, ndim, qfield)
        if not _divisible(leaf.shape, spec, mesh):
            return P()
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


# ----------------------------------------------------------------- batch ----
def batch_specs(batch_shape: Dict[str, Any], mesh) -> Dict[str, Any]:
    """Shard every batch input along its leading (batch) dim when divisible."""
    dp = batch_axes(mesh)
    n_dp = int(np.prod([dict(mesh.shape)[a] for a in dp]))

    def spec(path, leaf):
        b = leaf.shape[0] if leaf.shape else 0
        if leaf.ndim >= 1 and b % n_dp == 0 and b > 0:
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


# ----------------------------------------------------------------- cache ----
def cache_specs_tree(cache_shape, mesh) -> Any:
    """Decode-cache specs: batch → data axes; sequence → model (SP); SSM
    state heads/channels → model.  Long-context batch=1 shards the sequence
    over every axis."""
    sizes = dict(mesh.shape)
    dp = batch_axes(mesh)
    n_dp = int(np.prod([sizes[a] for a in dp]))
    n_model = sizes[MODEL]

    def spec(path, leaf):
        ps = _path_str(path)
        shp = leaf.shape
        name = ps.rsplit("/", 1)[1]
        lead = len(shp) - _cache_rank(name)
        b_idx = lead  # batch dim position after stacked-layer dims
        if name == "lens":
            return P(*([None] * lead), dp if shp[b_idx] % n_dp == 0 else None)
        if name in ("k", "v", "ckv", "kpe", "xk", "xv", "k_s", "v_s"):
            # [*, B, S, ...]: shard B over data, S over model (SP decode)
            b, s = shp[b_idx], shp[b_idx + 1]
            if b % n_dp == 0:
                baxis, saxis = dp, (MODEL,) if s % n_model == 0 else None
            else:
                baxis = None
                all_ax = dp + (MODEL,)
                n_all = n_dp * n_model
                saxis = all_ax if s % n_all == 0 else (
                    (MODEL,) if s % n_model == 0 else None)
            rest = len(shp) - b_idx - 2
            return P(*([None] * lead), baxis, saxis, *([None] * rest))
        if name in ("h",):      # mamba [*, B, H, P, N]
            b, h = shp[b_idx], shp[b_idx + 1]
            return P(*([None] * lead),
                     dp if b % n_dp == 0 else None,
                     MODEL if h % n_model == 0 else None,
                     *([None] * (len(shp) - b_idx - 2)))
        if name in ("wkv",):    # rwkv [*, B, H, K, V]
            b, h = shp[b_idx], shp[b_idx + 1]
            return P(*([None] * lead),
                     dp if b % n_dp == 0 else None,
                     MODEL if h % n_model == 0 else None,
                     *([None] * (len(shp) - b_idx - 2)))
        if name in ("conv_x",):  # [*, B, K-1, d_inner]
            b, _, c = shp[b_idx], shp[b_idx + 1], shp[b_idx + 2]
            return P(*([None] * lead),
                     dp if b % n_dp == 0 else None, None,
                     MODEL if c % n_model == 0 else None)
        # conv_bc, x_prev, ffn_prev: batch only
        b = shp[b_idx] if len(shp) > b_idx else 0
        rest = len(shp) - b_idx - 1
        return P(*([None] * lead),
                 dp if b and b % n_dp == 0 else None, *([None] * rest))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def _cache_rank(name: str) -> int:
    """Rank of one cache leaf EXCLUDING stacked layer dims."""
    return {
        "k": 4, "v": 4, "xk": 4, "xv": 4, "ckv": 3, "kpe": 3, "lens": 1,
        "k_s": 3, "v_s": 3,
        "h": 4, "conv_x": 3, "conv_bc": 3, "wkv": 4, "x_prev": 2,
        "ffn_prev": 2,
    }[name]


def logits_spec(mesh) -> P:
    return P(batch_axes(mesh), None, MODEL)


def logits_prefill_spec(mesh, batch: int, vocab: int) -> P:
    """Prefill returns last-token logits [B, V]: batch over data, V over model."""
    sizes = dict(mesh.shape)
    dp = batch_axes(mesh)
    n_dp = int(np.prod([sizes[a] for a in dp]))
    b_ax = dp if batch % n_dp == 0 else None
    v_ax = MODEL if vocab % sizes[MODEL] == 0 else None
    return P(b_ax, v_ax)


def logits_decode_spec(mesh, batch: int, vocab: int) -> P:
    sizes = dict(mesh.shape)
    v_ax = MODEL if vocab % sizes[MODEL] == 0 else None
    return P(None, v_ax)  # decode batch may be small (long_500k B=1)


# ------------------------------------------------------ optimizer (ZeRO) ----
def opt_specs(opt_shape, pspecs, mesh) -> Any:
    """ZeRO-style optimizer-state sharding: mu/nu/ef take the param's spec
    PLUS a "data" sharding on the first dim whose axis is free and divisible
    — so Adam moments never replicate across the data axis (123B × 8 bytes of
    moments would otherwise live on every data replica)."""
    sizes = dict(mesh.shape)
    n_data = sizes[DATA]

    def zeroify(spec: P, shape) -> P:
        axes = list(spec) + [None] * (len(shape) - len(spec))
        for i, (ax, dim) in enumerate(zip(axes, shape)):
            if ax is None and dim % n_data == 0 and dim > 0:
                axes[i] = DATA
                return P(*axes)
        return P(*axes)

    import dataclasses as _dc

    mu = jax.tree.map(
        lambda sp, leaf: zeroify(sp, leaf.shape), pspecs, opt_shape.mu
    )
    nu = jax.tree.map(
        lambda sp, leaf: zeroify(sp, leaf.shape), pspecs, opt_shape.nu
    )
    ef = None
    if opt_shape.ef is not None:
        ef = jax.tree.map(
            lambda sp, leaf: zeroify(sp, leaf.shape), pspecs, opt_shape.ef
        )
    from repro.optim.adamw import OptState

    return OptState(step=P(), mu=mu, nu=nu, ef=ef)
