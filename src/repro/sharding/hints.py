"""Sharding hints usable from model code without hard mesh coupling.

Model code calls ``shard_hint(x, "data", None, "model", None)``; if a mesh
has been installed (the pjit launchers do it), this becomes
``with_sharding_constraint`` — anchoring GSPMD's layout propagation at the
spots where it otherwise picks replicate-and-gather (e.g. around sequential
scans).  With no mesh installed (CPU unit tests), it is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars
import os
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar = contextvars.ContextVar("hint_mesh", default=None)


@contextlib.contextmanager
def hint_mesh(mesh):
    tok = _MESH.set(mesh)
    try:
        yield
    finally:
        _MESH.reset(tok)


def current_mesh():
    return _MESH.get()


def shard_hint(x: jax.Array, *axes) -> jax.Array:
    """Constrain x to PartitionSpec(*axes) if a hint mesh is installed.

    Axis entries that don't divide the corresponding dim are dropped
    (replicated) so hints are always safe.
    """
    mesh = _MESH.get()
    if mesh is None or os.environ.get("REPRO_NO_HINTS"):
        return x
    sizes = dict(mesh.shape)
    fixed = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            fixed.append(None)
            continue
        group = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                      if a in sizes)  # drop axes absent from this mesh
        if not group:
            fixed.append(None)
            continue
        n = 1
        for a in group:
            n *= sizes[a]
        fixed.append((group if len(group) > 1 else group[0])
                     if dim % n == 0 else None)
    fixed += [None] * (x.ndim - len(fixed))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed))
    )
