"""Fault-tolerant checkpointing: atomic, sharding-agnostic, elastic.

Design (multi-thousand-node ready):
- **Atomic**: write to ``step_N.tmp/`` then ``os.rename`` → a crash mid-write
  never corrupts the latest valid checkpoint; restore picks the highest
  complete step.
- **Sharding-agnostic**: leaves are saved as full logical arrays keyed by
  tree path (npz).  On restore they are ``jax.device_put`` with whatever
  sharding the *new* mesh prescribes — so a job can restart on a different
  topology (elastic re-mesh: 512 → 256 chips, etc.).  On a real multi-host
  cluster each host would write only its addressable shards (same layout,
  per-host files) — single-process here, noted in DESIGN.md.
- **Self-describing**: step, data-pipeline cursor, rng seed and user metadata
  ride along, so train.py resumes bit-exactly (counter-based data pipeline).
- **Retention**: keep the last K checkpoints (bounded disk).
- **Preemption**: ``install_sigterm_checkpoint`` saves on SIGTERM — the
  standard preemption hook for TPU pods.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

SEP = "␟"  # path separator unlikely to appear in keys


def _to_numpy(leaf) -> Tuple[np.ndarray, str]:
    """(npz-safe array, original dtype name).  bf16 (ml_dtypes, which npz
    can't store) is widened to f32; loaders narrow back via the dtype name."""
    dtype = str(jax.numpy.asarray(leaf).dtype)
    arr = np.asarray(leaf)
    if arr.dtype.kind == "V":
        arr = np.asarray(jax.numpy.asarray(leaf).astype(jax.numpy.float32))
    return arr, dtype


def _publish_dir(tmp: Path, final: Path) -> None:
    """Atomically publish ``tmp`` as ``final``.  ``os.replace`` cannot swap
    non-empty directories, so an existing ``final`` is renamed aside first: a
    crash between the renames loses nothing — the previous version survives
    as ``<name>.old`` and readers simply see no published dir until retry."""
    if final.exists():
        old = final.with_name(final.name + ".old")
        if old.exists():
            shutil.rmtree(old)
        os.replace(final, old)
        os.replace(tmp, final)
        shutil.rmtree(old, ignore_errors=True)
    else:
        os.replace(tmp, final)


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = _to_numpy(leaf)[0]
    return out


def _unflatten_into(tree, flat: Dict[str, np.ndarray]):
    def fill(path, leaf):
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        return arr
    return jax.tree_util.tree_map_with_path(fill, tree)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------- write ---
    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(tree)
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "meta.json").write_text(json.dumps(
            {"step": step, **(metadata or {})}, default=str))
        _publish_dir(tmp, final)        # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -------------------------------------------------------------- read ---
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "meta.json").exists():
                continue  # incomplete write — ignored (fault tolerance)
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, like: Any, step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like``; place onto ``shardings``
        (a NamedSharding tree) if given — this is the elastic-re-mesh path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        with np.load(d / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(like, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        else:
            # two-step cast: numpy can't cast directly into ml_dtypes bf16
            tree = jax.tree.map(
                lambda a, l: jax.numpy.asarray(a).astype(l.dtype), tree, like
            )
        meta = json.loads((d / "meta.json").read_text())
        return tree, meta


# ------------------------------------------------------- PTQ artifacts -----
# Quantize-once / serve-many: a PTQ artifact is a directory holding the
# *quantized* param pytree (QuantizedTensor leaves flattened to
# ``path␟packed`` / ``␟scales`` / ``␟zeros`` npz entries — packed stays uint8
# through the round trip) plus a self-describing ``meta.json`` (config hash,
# per-leaf dtypes, quantized paths, PTQ report).  Written atomically like
# train checkpoints (tmp dir + rename), so a crash mid-save never publishes a
# half artifact.  ``core.apply.save_ptq/load_ptq`` are the typed entry points.

PTQ_FORMAT_VERSION = 1
_QT_FIELDS = ("packed", "scales", "zeros")


def _walk_ptq(tree, prefix=()):
    """Yield (path, leaf) pairs, keeping QuantizedTensor leaves whole."""
    from repro.core.quantize import QuantizedTensor

    if isinstance(tree, QuantizedTensor):
        yield prefix, tree
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk_ptq(tree[k], prefix + (str(k),))
    elif tree is None:
        return
    else:
        yield prefix, tree


def save_ptq_artifact(directory: str | Path, tree: Any,
                      meta: Optional[Dict] = None) -> Path:
    """Atomically write a quantized param pytree + metadata to ``directory``."""
    final = Path(directory)
    tmp = final.with_name(final.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    qpaths = []
    from repro.core.quantize import QuantizedTensor

    for path, leaf in _walk_ptq(tree):
        key = SEP.join(path)
        if isinstance(leaf, QuantizedTensor):
            qpaths.append(list(path))
            for f in _QT_FIELDS:
                fkey = key + SEP + f
                flat[fkey], dtypes[fkey] = _to_numpy(getattr(leaf, f))
        else:
            flat[key], dtypes[key] = _to_numpy(leaf)
    np.savez(tmp / "arrays.npz", **flat)
    (tmp / "meta.json").write_text(json.dumps({
        "format_version": PTQ_FORMAT_VERSION,
        "quantized": qpaths,
        "dtypes": dtypes,
        **(meta or {}),
    }))
    _publish_dir(tmp, final)        # atomic publish (old version kept aside)
    return final


def has_ptq_artifact(directory: str | Path) -> bool:
    d = Path(directory)
    return (d / "meta.json").exists() and (d / "arrays.npz").exists()


def load_ptq_artifact(directory: str | Path) -> Tuple[Any, Dict]:
    """Rebuild the quantized pytree (QuantizedTensor leaves re-assembled,
    dtypes restored) from :func:`save_ptq_artifact` output."""
    from repro.core.quantize import QuantizedTensor

    d = Path(directory)
    if not has_ptq_artifact(d):
        raise FileNotFoundError(f"no PTQ artifact at {d}")
    meta = json.loads((d / "meta.json").read_text())
    if meta.get("format_version") != PTQ_FORMAT_VERSION:
        raise ValueError(
            f"PTQ artifact format {meta.get('format_version')} != "
            f"{PTQ_FORMAT_VERSION}")
    dtypes = meta["dtypes"]
    with np.load(d / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files}

    tree: Dict[str, Any] = {}
    for key, arr in flat.items():
        leaf = jax.numpy.asarray(arr).astype(dtypes[key])
        parts = key.split(SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf

    def assemble(path):
        node = tree
        for p in path[:-1]:
            node = node[p]
        node[path[-1]] = QuantizedTensor(**{
            f: node[path[-1]][f] for f in _QT_FIELDS})

    for qp in meta["quantized"]:
        assemble(qp)
    return tree, meta


def install_sigterm_checkpoint(save_fn: Callable[[], None]):
    """Checkpoint-on-preemption: call ``save_fn`` once on SIGTERM, then
    re-raise the default handler so the scheduler sees a clean exit."""
    prev = signal.getsignal(signal.SIGTERM)

    def handler(signum, frame):
        try:
            save_fn()
        finally:
            signal.signal(signal.SIGTERM, prev)
            signal.raise_signal(signal.SIGTERM)

    signal.signal(signal.SIGTERM, handler)
    return handler
