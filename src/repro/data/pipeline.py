"""Deterministic, resumable synthetic token pipeline.

Every batch is a pure function of (seed, step) via counter-based PRNG —
restart/resume = set the step counter (no iterator state to snapshot), and
elastic re-sharding is trivial because the GLOBAL batch is deterministic and
each host slices its own shard.  This is the standard fault-tolerant data
design (tf.data-with-checkpoints replaced by a stateless map).

The token stream is a Zipf-distributed language-like mixture with injected
long-range copy structure (so a ~100M-param model trained on it shows a
clearly decreasing loss — used by examples/train_small.py)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    zipf_a: float = 1.2
    copy_period: int = 64          # long-range structure for learnability


class SyntheticTokens:
    """Stateless batch source: ``batch_at(step)`` for any step, any time."""

    def __init__(self, dc: DataConfig, cfg: Optional[ModelConfig] = None):
        self.dc = dc
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        dc = self.dc
        rng = np.random.default_rng((dc.seed << 32) ^ step)
        ranks = rng.zipf(dc.zipf_a, size=(dc.global_batch, dc.seq_len + 1))
        toks = (ranks % (dc.vocab_size - 2) + 2).astype(np.int32)
        # inject copy structure: every copy_period-th token repeats the token
        # copy_period//2 positions earlier — learnable signal
        p = dc.copy_period
        idx = np.arange(dc.seq_len + 1)
        src = idx - p // 2
        mask = (idx % p == 0) & (src >= 0)
        toks[:, mask] = toks[:, src[mask]]
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if self.cfg is not None and self.cfg.encdec:
            frng = np.random.default_rng((dc.seed << 32) ^ step ^ 0xF00D)
            batch["frames"] = jnp.asarray(
                frng.standard_normal(
                    (dc.global_batch, dc.seq_len, self.cfg.d_model), np.float32
                )
            ).astype(self.cfg.jdtype)
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, jax.Array]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
