"""Shared-prefix KV cache: block-hash index over pool pages + LRU eviction.

Many live requests share a long system / few-shot prompt.  Without reuse,
every such request re-prefills the shared prefix from scratch *and* holds a
private copy of identical pages — wasted FLOPs and wasted pool pages.  This
module gives the pager an **automatic prefix cache** (the vLLM
automatic-prefix-caching design, block-granular):

- every *full* page of a sequence gets a **chained block hash**:
  ``h_i = H(h_{i-1}, token_ids(page_i))``, rooted in the pool's KV
  quantization mode — int8 and fp16 pools can never cross-match, and a page
  is only reachable through the exact token prefix that produced it;
- the index maps chain hash → resident pool page.  Matching a new prompt
  walks its full pages front-to-back and stops at the first miss, so a hit
  is always a *prefix* of whole pages;
- cached pages are **read-only**; the pool keeps them resident after the
  last slot reference drops (refcount 0 + cached = *evictable*) and this
  cache reclaims them **LRU-first** through the pool's evictor hook exactly
  when an allocation would otherwise fail — cached-but-unreferenced pages
  are free memory in waiting, never a reservation;
- the hash chain is over *tokens*, not pages, so evicting a parent simply
  makes descendants unmatchable until the prefix is re-inserted; a dangling
  entry can never alias wrong content.

The scheduler calls :meth:`match` + ``PagePool.attach`` at admission (the
engine then prefills only the uncached suffix), and the engine calls
:meth:`insert` with a slot's full pages after prefill and again when the
slot finishes, so generated tokens become matchable too (multi-turn reuse).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_cache import PagePool


@dataclasses.dataclass
class PrefixCacheStats:
    lookups: int = 0            # match() calls
    hits: int = 0               # match() calls returning >= 1 page
    matched_tokens: int = 0     # sum of matched whole-page tokens
    inserted_pages: int = 0     # pages newly indexed
    evicted_pages: int = 0      # unreferenced cached pages reclaimed


class PrefixCache:
    """Block-hash index + LRU evictor over a :class:`PagePool`.

    ``mode`` is folded into the root hash so pools with different on-device
    row encodings (fp16 vs int8+scales) never share pages.
    """

    def __init__(self, pool: PagePool, page_size: int, *, mode: str = ""):
        self.pool = pool
        self.page_size = page_size
        self._root = hashlib.sha256(mode.encode()).digest()
        self._index: Dict[bytes, int] = {}     # chain hash -> pool page
        self._by_page: Dict[int, bytes] = {}   # pool page -> chain hash
        self._lru: Dict[int, int] = {}         # evictable page -> last-use tick
        self._clock = 0
        self.stats = PrefixCacheStats()
        self.faults = None                     # FaultPlan (or None)
        pool.set_evictor(self)

    # ------------------------------------------------------------- hashing --
    def block_hashes(self, tokens, head=()) -> List[bytes]:
        """Chained hash per *full* page of ``tokens``.

        ``head`` may carry already-computed hashes for the leading pages
        (e.g. a request's memoized prompt hashes when hashing prompt +
        generated tokens at slot finish) — full pages never straddle the
        prompt/generation boundary, so a prompt-page hash is a combined-
        sequence page hash verbatim and only the continuation is chained.
        """
        toks = np.asarray(tokens, np.int32)
        n = len(toks) // self.page_size
        out = list(head[:n])
        h = out[-1] if out else self._root
        for i in range(len(out), n):
            blk = toks[i * self.page_size : (i + 1) * self.page_size]
            h = hashlib.sha256(h + blk.tobytes()).digest()
            out.append(h)
        return out

    # ------------------------------------------------------ match / insert --
    def match(self, tokens, hashes: Optional[List[bytes]] = None,
              probe_faults: bool = True) -> Tuple[List[int], int]:
        """Longest cached whole-page prefix of ``tokens``.

        Returns ``(pages, matched_tokens)``.  Matched evictable pages are
        LRU-touched, so an immediately following ``pool.attach`` cannot lose
        them to an eviction triggered by the same admission plan.  Pass
        precomputed ``hashes`` (:meth:`block_hashes` — pure in the tokens) to
        skip re-chain-hashing: a blocked queue head is re-matched every
        engine step, and only the index lookups can change between steps.
        ``probe_faults=False`` marks a diagnostic-only match (the admission
        stall report): it must never consume fault-plan budget or evict.
        """
        self.stats.lookups += 1
        pages: List[int] = []
        for h in (hashes if hashes is not None
                  else self.block_hashes(tokens)):
            p = self._index.get(h)
            if p is None:
                break
            pages.append(p)
        if pages and probe_faults and self.faults is not None \
                and self.faults.fires("prefix_evict"):
            # forced eviction under attach: the matched pages vanish between
            # match and attach (the race the LRU touch below normally closes).
            # Evict every matched page that is currently evictable and report
            # a miss — the admission degrades to a cold prefill, which the
            # identity tests prove is token-equivalent.
            for p in pages:
                if p in self._lru:
                    self._evict_page(p)
            pages = []
        self._clock += 1
        for p in pages:
            if p in self._lru:
                self._lru[p] = self._clock
        if pages:
            self.stats.hits += 1
            self.stats.matched_tokens += len(pages) * self.page_size
        return pages, len(pages) * self.page_size

    def insert(self, tokens, pages: List[int], n_full: int,
               hashes: Optional[List[bytes]] = None) -> int:
        """Index the first ``n_full`` pages of a slot's written sequence.

        Idempotent: a chain hash already indexed is skipped (this is how a
        COW duplicate of a cached page, or a re-insert at slot finish, stays
        un-indexed — the canonical first copy wins).  The slot must still
        reference the pages (they are marked read-only in the pool here).
        ``hashes`` skips re-chain-hashing like in :meth:`match`.
        Returns the number of pages newly indexed.
        """
        inserted = 0
        if hashes is None:
            hashes = self.block_hashes(tokens)
        for h, p in zip(hashes[:n_full], pages[:n_full]):
            if h in self._index or p in self._by_page:
                continue
            self._index[h] = p
            self._by_page[p] = h
            self.pool.mark_cached(p)
            inserted += 1
        self.stats.inserted_pages += inserted
        return inserted

    # ----------------------------------------------- exact-match (read-only) --
    def data_hashes(self, data, n_pages: int, tag: str = "enc") -> List[bytes]:
        """Whole-sequence keyed page hashes for read-only page groups
        (encoder cross-attention K/V).

        ``data`` is the full host array the pages were derived from (a
        request's encoder frames).  A bidirectional encoder sees every
        frame, so a page is only reusable when the *entire* sequence
        matches — chaining prefix hashes (the :meth:`block_hashes` scheme)
        would alias pages of different sequences that share a prefix.  The
        whole sequence is hashed into one key and per-page hashes are
        derived from (key, page index), so :meth:`match_exact` is
        all-or-nothing by construction."""
        a = np.ascontiguousarray(np.asarray(data))
        key = hashlib.sha256(
            self._root + tag.encode() + str(a.shape).encode() + a.tobytes()
        ).digest()
        return [hashlib.sha256(key + i.to_bytes(4, "little")).digest()
                for i in range(n_pages)]

    def match_exact(self, hashes: List[bytes],
                    probe_faults: bool = True) -> List[int]:
        """All-or-nothing lookup of a :meth:`data_hashes` page set.

        Returns the cached pages (ready for ``pool.attach(...,
        group="enc")``) or ``[]`` — a partially evicted set is a miss (the
        survivors stay resident until LRU reclaims them; they can never
        alias other content).  Matched evictable pages are LRU-touched like
        in :meth:`match`.  The ``enc_evict`` fault site forces the matched
        set out between match and attach, degrading the admission to a
        fresh encode."""
        self.stats.lookups += 1
        pages = [self._index.get(h) for h in hashes]
        if not pages or any(p is None for p in pages):
            return []
        if probe_faults and self.faults is not None \
                and self.faults.fires("enc_evict"):
            for p in pages:
                if p in self._lru:
                    self._evict_page(p)
            return []
        self._clock += 1
        for p in pages:
            if p in self._lru:
                self._lru[p] = self._clock
        self.stats.hits += 1
        self.stats.matched_tokens += len(pages) * self.page_size
        return pages

    def insert_exact(self, hashes: List[bytes], pages: List[int]) -> int:
        """Index a slot's read-only pages under :meth:`data_hashes` keys.
        Idempotent like :meth:`insert`; the slot must still reference the
        pages.  Returns the number of pages newly indexed."""
        inserted = 0
        for h, p in zip(hashes, pages):
            if h in self._index or p in self._by_page:
                continue
            self._index[h] = p
            self._by_page[p] = h
            self.pool.mark_cached(p)
            inserted += 1
        self.stats.inserted_pages += inserted
        return inserted

    # ------------------------------------------------------- evictor hooks --
    def on_unreferenced(self, page: int) -> None:
        """Pool callback: a cached page's last reference dropped → evictable."""
        self._clock += 1
        self._lru[page] = self._clock

    def on_referenced(self, page: int) -> None:
        """Pool callback: an evictable page was re-attached → pinned."""
        self._lru.pop(page, None)

    def evictable_count(self) -> int:
        return len(self._lru)

    def evictable_page_ids(self):
        return self._lru.keys()

    def evict_one(self) -> bool:
        """Reclaim the least-recently-used unreferenced cached page (drop its
        index entry, return the page to the pool's free list)."""
        if not self._lru:
            return False
        self._evict_page(min(self._lru, key=self._lru.get))
        return True

    def _evict_page(self, page: int) -> None:
        """Evict one specific *evictable* page (LRU pick or forced)."""
        del self._lru[page]
        h = self._by_page.pop(page)
        del self._index[h]
        self.pool.release_cached(page)
        self.stats.evicted_pages += 1

    # --------------------------------------------------------------- misc ---
    def __len__(self) -> int:
        return len(self._index)
