"""Admission scheduler: length-bucketed batched prefill planning.

The seed engine prefilled one request at a time (one compiled B=1 trace per
prompt length).  This scheduler instead admits *every* runnable queued
request in one engine step and groups them into **length buckets** (powers of
two of the page size), so each bucket compiles one joint ``[n, bucket_len]``
prefill and the number of distinct traces stays O(log max_seq) instead of
O(#prompt lengths).

Admission is strict FCFS: the queue head is admitted only if a free slot and
enough free pages exist; nothing behind it jumps ahead (no starvation).  A
``max_prefill_tokens`` budget bounds the padded tokens prefilled in a single
engine step — oversized backlogs are drained in chunks across steps so decode
latency of in-flight requests stays bounded.

**Page reservation** (``reservation=``): ``"lazy"`` (default) reserves only
the pages covering the prompt plus one decode token — the engine grows the
page table during decode and preempts on pool pressure, so pool occupancy
tracks *live* tokens and concurrency is bounded by real memory, not by the
worst case.  ``"worstcase"`` reserves ``prompt + max_tokens`` pages up front
(no growth or preemption ever needed) — kept as the benchmark baseline the
paper's single-A100 deployment story argues against.

**Watermark**: under lazy reservation the head is admitted only while
``free_pages >= need + reserve``, where ``reserve`` starts at the number of
already-decoding slots (passed by the engine) and rises by one per admitted
request.  Each live slot thus keeps about one page of growth headroom, so
preemption is the rare pressure-relief valve, not a steady-state tax.  The
reserve is waived when nothing is active (``reserve=0``) so an empty engine
can always admit its head and never deadlocks on its own watermark.

**Prefix cache** (``cache=`` on :meth:`Scheduler.plan`): the head's prompt
is matched against the block-hash index first.  Matched whole pages are
*attached* (shared, refcounted — no allocation, no prefill) and admission is
charged only for the **uncached suffix**; buckets are keyed by the suffix's
bucket length, so a 2000-token prompt behind a warm system prefix competes
for prefill budget like the 20-token suffix it actually is.  At least one
token is always prefilled (the engine needs last-token logits to sample):
when the whole prompt is cached (a page-aligned full match) the plan takes a
**copy-on-write** of the final matched page and re-prefills just the last
prompt token into the private copy.

``mode="slotwise"`` degenerates to one request per bucket at its exact prompt
length — the seed engine's prefill strategy — kept as the benchmark baseline.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.serving.kv_cache import PagePool


@dataclasses.dataclass
class PrefillBucket:
    pad_len: int          # joint prefill length (suffix tokens)
    reqs: list            # admitted Requests, FCFS order
    slots: List[int]      # slot id per request
    needs: List[int]      # fresh pages allocated per request
    prefix_lens: List[int] = dataclasses.field(default_factory=list)
    # matched prefix tokens per request (0 = cold)
    shared: List[int] = dataclasses.field(default_factory=list)
    # pages attached (shared, not allocated) per request
    cow: List[Optional[Tuple[int, int]]] = dataclasses.field(
        default_factory=list)
    # (src, dst) pool pages whose rows the engine must copy before prefill


class Scheduler:
    def __init__(self, *, page_size: int, max_seq: int,
                 max_prefill_tokens: Optional[int] = None,
                 mode: str = "bucketed", reservation: str = "lazy"):
        if mode not in ("bucketed", "slotwise"):
            raise ValueError(f"unknown prefill mode {mode!r}")
        if reservation not in ("lazy", "worstcase"):
            raise ValueError(f"unknown page reservation {reservation!r}")
        self.page_size = page_size
        self.max_seq = max_seq
        self.max_prefill_tokens = max_prefill_tokens
        self.mode = mode
        self.reservation = reservation

    def bucket_len(self, prompt_len: int) -> int:
        b = self.page_size
        while b < prompt_len:
            b *= 2
        return min(b, self.max_seq)

    def _tokens_wanted(self, req) -> int:
        if self.reservation == "worstcase":
            return min(len(req.prompt) + req.max_tokens, self.max_seq)
        # lazy: cover the prompt plus the first decode write only; the
        # engine grows the table page-by-page as decode proceeds
        return min(len(req.prompt) + 1, self.max_seq)

    def pages_needed(self, req, pool: PagePool, cache=None) -> int:
        """Fresh-page cost of admitting ``req`` (cold total without
        ``cache``; with it, the matched whole-page prefix is subtracted and a
        page-aligned full match pays one extra page for its COW copy) —
        diagnostic twin of the arithmetic :meth:`plan` performs."""
        total = pool.pages_needed(self._tokens_wanted(req))
        if cache is None:
            return total
        matched, mtok = cache.match(
            req.prompt, hashes=getattr(req, "_block_hashes", None))
        full_match = bool(matched) and mtok == len(req.prompt)
        return total - len(matched) + (1 if full_match else 0)

    def plan(self, queue: Deque, free_slots: List[int], pool: PagePool,
             reserve: int = 0, cache=None) -> List[PrefillBucket]:
        """Pop admissible requests off ``queue`` and bucket them.

        Reserves pages in ``pool`` for every admitted request (so a later
        bucket in the same step can't oversubscribe) and assigns slots.
        ``reserve`` is the admission watermark: free pages that must remain
        after each admit (one growth page per decoding slot — the engine
        passes its active-slot count, and each admission here adds one).
        With ``cache`` (a ``PrefixCache``), matched whole-page prefixes are
        attached shared and only the uncached suffix is charged/prefilled.
        """
        slots = deque(free_slots)
        budget = self.max_prefill_tokens
        buckets: dict = {}
        spent = 0
        while queue and slots:
            req = queue[0]
            t = len(req.prompt)
            # cheap pre-filter before hashing the prompt: no match can need
            # fewer than one fresh page, so a drained pool blocks the head
            # without re-chain-hashing a long prompt every engine step
            if not pool.can_alloc(1 + reserve):
                break
            if cache is not None:       # not truthiness: empty index matches
                # chain hashes are pure in the prompt tokens: compute them
                # once per request, not once per engine step while blocked
                hs = getattr(req, "_block_hashes", None)
                if hs is None:
                    hs = req._block_hashes = cache.block_hashes(req.prompt)
                matched, mtok = cache.match(req.prompt, hashes=hs)
            else:
                matched, mtok = [], 0
            # never admit a zero-token prefill: the engine samples the first
            # output from the last prompt token's logits, so a page-aligned
            # full match re-prefills that one token into a COW'd private
            # copy of the final matched page
            full_match = matched and mtok == t
            suffix = 1 if full_match else t - mtok
            prefix = t - suffix
            total = pool.pages_needed(self._tokens_wanted(req))
            fresh = total - len(matched) + (1 if full_match else 0)
            # matched-but-unreferenced pages are about to be *pinned* by the
            # attach below, so they must not be double-counted as evictable
            # headroom for the fresh allocation — otherwise attach + grow
            # would blow up on a pool whose only evictable pages are the very
            # ones this request is re-using
            pinned = sum(1 for p in matched if pool.page_ref(p) == 0)
            if not pool.can_alloc(fresh + reserve + pinned):
                break                       # FCFS: head blocks the line
            blen = (suffix if self.mode == "slotwise"
                    else self.bucket_len(suffix))
            if budget is not None and spent and spent + blen > budget:
                break                       # chunk the backlog across steps
            queue.popleft()
            slot = slots.popleft()
            if matched:
                pool.attach(slot, matched)
            # hold_src: the engine performs the src→dst device copy later
            # (per bucket, before its prefill); the hold pins src so no
            # allocation in the rest of this plan can reclaim + overwrite it
            # first — the engine drops the hold right after the copy
            cow_pair = (pool.cow(slot, len(matched) - 1, hold_src=True)
                        if full_match else None)
            if fresh - (1 if full_match else 0):
                pool.grow(slot, fresh - (1 if full_match else 0))
            if self.reservation == "lazy":
                reserve += 1                # growth headroom for the new slot
            shared = len(matched) - (1 if full_match else 0)
            key = (blen if self.mode == "bucketed" else (blen, slot),
                   prefix > 0)
            bkt = buckets.get(key)
            if bkt is None:
                bkt = buckets[key] = PrefillBucket(blen, [], [], [])
            bkt.reqs.append(req)
            bkt.slots.append(slot)
            bkt.needs.append(fresh)
            bkt.prefix_lens.append(prefix)
            bkt.shared.append(shared)
            bkt.cow.append(cow_pair)
            spent += blen
        return list(buckets.values())
