"""Admission scheduler: length-bucketed batched prefill planning.

The seed engine prefilled one request at a time (one compiled B=1 trace per
prompt length).  This scheduler instead admits *every* runnable queued
request in one engine step and groups them into **length buckets** (powers of
two of the page size), so each bucket compiles one joint ``[n, bucket_len]``
prefill and the number of distinct traces stays O(log max_seq) instead of
O(#prompt lengths).

Admission is strict FCFS: the queue head is admitted only if a free slot and
enough free pages exist; nothing behind it jumps ahead (no starvation).

**Mixed steps** (:meth:`Scheduler.plan_chunks`): admission only assigns slots
and pages — the prompt tokens themselves prefill in *chunks*.  Every engine
step packs up to ``max_prefill_tokens`` actual chunk tokens across the slots
still prefilling (per-slot chunk cursor = tokens already written) alongside
the step's decode batch, vLLM/Sarathi-style, so long prompts drain across
consecutive steps while decode inter-token latency stays bounded.  Non-final
chunks end on page boundaries (later chunks start page-aligned); the head
always makes progress even when the budget is smaller than a page.

**Page reservation** (``reservation=``): ``"lazy"`` (default) reserves only
the pages covering the prompt plus one decode token — the engine grows the
page table during decode and preempts on pool pressure, so pool occupancy
tracks *live* tokens and concurrency is bounded by real memory, not by the
worst case.  ``"worstcase"`` reserves ``prompt + max_tokens`` pages up front
(no growth or preemption ever needed) — kept as the benchmark baseline the
paper's single-A100 deployment story argues against.

**Watermark**: under lazy reservation the head is admitted only while
``free_pages >= need + reserve``, where ``reserve`` starts at the number of
already-decoding slots (passed by the engine) and rises by one per admitted
request.  Each live slot thus keeps about one page of growth headroom, so
preemption is the rare pressure-relief valve, not a steady-state tax.  The
reserve is waived when nothing is active (``reserve=0``) so an empty engine
can always admit its head and never deadlocks on its own watermark.

**Prefix cache** (``cache=`` on :meth:`Scheduler.plan`): the head's prompt
is matched against the block-hash index first.  Matched whole pages are
*attached* (shared, refcounted — no allocation, no prefill) and admission is
charged only for the **uncached suffix**; buckets are keyed by the suffix's
bucket length, so a 2000-token prompt behind a warm system prefix competes
for prefill budget like the 20-token suffix it actually is.  At least one
token is always prefilled (the engine needs last-token logits to sample):
when the whole prompt is cached (a page-aligned full match) the plan takes a
**copy-on-write** of the final matched page and re-prefills just the last
prompt token into the private copy.

``mode="slotwise"`` degenerates to one request per bucket at its exact prompt
length — the seed engine's prefill strategy — kept as the benchmark baseline.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.serving.faults import TransientFault
from repro.serving.kv_cache import PagePool


@dataclasses.dataclass
class PrefillBucket:
    pad_len: int          # joint prefill length (suffix tokens)
    reqs: list            # admitted Requests, FCFS order
    slots: List[int]      # slot id per request
    needs: List[int]      # fresh pages allocated per request
    prefix_lens: List[int] = dataclasses.field(default_factory=list)
    # matched prefix tokens per request (0 = cold)
    shared: List[int] = dataclasses.field(default_factory=list)
    # pages attached (shared, not allocated) per request
    cow: List[Optional[Tuple[int, int]]] = dataclasses.field(
        default_factory=list)
    # (src, dst) pool pages whose rows the engine must copy before prefill


@dataclasses.dataclass
class ChunkBucket:
    """One fused ``[n, pad_len]`` prefill-chunk launch of a mixed step."""
    pad_len: int          # padded chunk length (power of two of page_size)
    slots: List[int]      # engine slot per row
    starts: List[int]     # tokens already written per row (chunk cursor)
    lens: List[int]       # valid chunk tokens per row (<= pad_len)
    final: List[bool]     # True when this chunk completes the row's prompt


@dataclasses.dataclass
class _AdmissionCost:
    """Page arithmetic for admitting one request — the single source shared
    by :meth:`Scheduler.plan` and its diagnostic twin
    :meth:`Scheduler.pages_needed`, so the admission-stall report can never
    drift from what admission actually charges."""
    total: int            # pages covering _tokens_wanted, ignoring the cache
    matched: list         # cached whole pages the prefix cache matched
    mtok: int             # tokens those pages cover
    full_match: bool      # page-aligned whole-prompt match (needs a COW)
    fresh: int            # pages to allocate (incl. the COW destination)
    pinned: int           # matched-but-unreferenced pages the attach pins
    enc: int = 0          # read-only encoder pages (enc-dec requests only)


class Scheduler:
    def __init__(self, *, page_size: int, max_seq: int,
                 max_prefill_tokens: Optional[int] = None,
                 mode: str = "bucketed", reservation: str = "lazy"):
        if mode not in ("bucketed", "slotwise"):
            raise ValueError(f"unknown prefill mode {mode!r}")
        if reservation not in ("lazy", "worstcase"):
            raise ValueError(f"unknown page reservation {reservation!r}")
        if max_prefill_tokens is not None and max_prefill_tokens < 1:
            raise ValueError(
                f"max_prefill_tokens must be >= 1, got {max_prefill_tokens}")
        self.page_size = page_size
        self.max_seq = max_seq
        self.max_prefill_tokens = max_prefill_tokens
        self.mode = mode
        self.reservation = reservation
        # True when the last plan() aborted an admission on an injected
        # transient fault (rolled back, request back at the queue head) —
        # the engine reads this to count a retry and to distinguish a
        # fault-induced idle step from a genuine admission stall
        self.last_plan_aborted = False
        # cumulative planning counters, surfaced by the engine's
        # metrics_snapshot(): how many plan passes ran, requests admitted,
        # chunk rounds launched and chunk tokens scheduled, and plans
        # aborted mid-admission by an injected fault
        self.counts = {"plans": 0, "admitted": 0, "chunk_rounds": 0,
                       "chunk_tokens": 0, "aborted_plans": 0}

    def bucket_len(self, prompt_len: int) -> int:
        b = self.page_size
        while b < prompt_len:
            b *= 2
        return min(b, self.max_seq)

    def _tokens_wanted(self, req) -> int:
        if self.reservation == "worstcase":
            return min(len(req.prompt) + req.max_tokens, self.max_seq)
        # lazy: cover the prompt plus the first decode write only; the
        # engine grows the table page-by-page as decode proceeds
        return min(len(req.prompt) + 1, self.max_seq)

    def _admission_cost(self, req, pool: PagePool, cache=None,
                        probe_faults: bool = True) -> _AdmissionCost:
        """The one admission page-arithmetic path (used by both :meth:`plan`
        and :meth:`pages_needed`): cold total, cache-matched prefix credit,
        the full-match COW page, and the matched-but-unreferenced pages the
        attach is about to pin (which must not double as evictable headroom
        for the fresh allocation).  Enc-dec requests (``req.frames``) are
        additionally charged the read-only encoder pages their frames cover
        — conservatively assumed fresh here; on an encoder-cache hit the
        engine frees them again and attaches the shared pages instead.
        ``probe_faults=False`` marks the diagnostic twin's call: it must not
        consume fault-plan budget."""
        total = pool.pages_needed(self._tokens_wanted(req))
        frames = getattr(req, "frames", None)
        enc = pool.pages_needed(len(frames)) if frames is not None else 0
        if cache is None:
            return _AdmissionCost(total, [], 0, False, total, 0, enc)
        # chain hashes are pure in the prompt tokens: compute them once per
        # request, not once per engine step while blocked
        hs = getattr(req, "_block_hashes", None)
        if hs is None:
            hs = req._block_hashes = cache.block_hashes(req.prompt)
        matched, mtok = cache.match(req.prompt, hashes=hs,
                                    probe_faults=probe_faults)
        full_match = bool(matched) and mtok == len(req.prompt)
        fresh = total - len(matched) + (1 if full_match else 0)
        pinned = sum(1 for p in matched if pool.page_ref(p) == 0)
        return _AdmissionCost(total, matched, mtok, full_match, fresh, pinned,
                              enc)

    def pages_needed(self, req, pool: PagePool, cache=None) -> int:
        """Pages that must be allocatable to admit ``req`` — the diagnostic
        twin of :meth:`plan`, sharing its arithmetic via
        :meth:`_admission_cost` (fresh pages plus the matched-but-unreferenced
        pages the attach would pin, plus an enc-dec request's encoder
        pages)."""
        cost = self._admission_cost(req, pool, cache, probe_faults=False)
        return cost.fresh + cost.pinned + cost.enc

    def plan(self, queue: Deque, free_slots: List[int], pool: PagePool,
             reserve: int = 0, cache=None) -> List[PrefillBucket]:
        """Pop admissible requests off ``queue`` and bucket them.

        Reserves pages in ``pool`` for every admitted request (so a later
        bucket in the same step can't oversubscribe) and assigns slots.
        ``reserve`` is the admission watermark: free pages that must remain
        after each admit (one growth page per decoding slot — the engine
        passes its active-slot count, and each admission here adds one).
        With ``cache`` (a ``PrefixCache``), matched whole-page prefixes are
        attached shared and only the uncached suffix is charged/prefilled.
        """
        slots = deque(free_slots)
        budget = self.max_prefill_tokens
        buckets: dict = {}
        spent = 0
        self.last_plan_aborted = False
        self.counts["plans"] += 1
        while queue and slots:
            req = queue[0]
            t = len(req.prompt)
            # cheap pre-filter before hashing the prompt: no match can need
            # fewer than one fresh page, so a drained pool blocks the head
            # without re-chain-hashing a long prompt every engine step
            if not pool.can_alloc(1 + reserve):
                break
            cost = self._admission_cost(req, pool, cache)
            matched, full_match = cost.matched, cost.full_match
            fresh = cost.fresh
            # never admit a zero-token prefill: the engine samples the first
            # output from the last prompt token's logits, so a page-aligned
            # full match re-prefills that one token into a COW'd private
            # copy of the final matched page
            suffix = 1 if full_match else t - cost.mtok
            prefix = t - suffix
            # matched-but-unreferenced pages are about to be *pinned* by the
            # attach below, so they must not be double-counted as evictable
            # headroom for the fresh allocation — otherwise attach + grow
            # would blow up on a pool whose only evictable pages are the very
            # ones this request is re-using
            if not pool.can_alloc(fresh + reserve + cost.pinned + cost.enc):
                break                       # FCFS: head blocks the line
            blen = (suffix if self.mode == "slotwise"
                    else self.bucket_len(suffix))
            if budget is not None and spent and spent + blen > budget:
                break                       # chunk the backlog across steps
            queue.popleft()
            slot = slots.popleft()
            cow_pair = None
            try:
                if matched:
                    pool.attach(slot, matched)
                # hold_src: the engine performs the src→dst device copy later
                # (per bucket, before its prefill); the hold pins src so no
                # allocation in the rest of this plan can reclaim + overwrite
                # it first — the engine drops the hold right after the copy
                cow_pair = (pool.cow(slot, len(matched) - 1, hold_src=True)
                            if full_match else None)
                if fresh - (1 if full_match else 0):
                    pool.grow(slot, fresh - (1 if full_match else 0))
                if cost.enc:
                    # read-only encoder pages, allocated fresh here; on an
                    # encoder-cache hit the engine frees them and attaches
                    # the shared cached pages instead
                    pool.grow(slot, cost.enc, group="enc")
            except TransientFault:
                # injected grow fault mid-admission: roll the whole admission
                # back (release attached pages + the COW copy and its hold,
                # requeue at the head — FCFS preserved) and stop planning;
                # the head simply retries next step
                if cow_pair is not None:
                    pool.drop_hold(cow_pair[0])
                pool.free_slot(slot)
                queue.appendleft(req)
                self.last_plan_aborted = True
                self.counts["aborted_plans"] += 1
                break
            if self.reservation == "lazy":
                reserve += 1                # growth headroom for the new slot
            shared = len(matched) - (1 if full_match else 0)
            key = (blen if self.mode == "bucketed" else (blen, slot),
                   prefix > 0)
            bkt = buckets.get(key)
            if bkt is None:
                bkt = buckets[key] = PrefillBucket(blen, [], [], [])
            bkt.reqs.append(req)
            bkt.slots.append(slot)
            bkt.needs.append(fresh)
            self.counts["admitted"] += 1
            bkt.prefix_lens.append(prefix)
            bkt.shared.append(shared)
            bkt.cow.append(cow_pair)
            spent += blen
        return list(buckets.values())

    def plan_chunks(self, prefilling: List[Tuple[int, int, int]],
                    budget: Optional[int] = None) -> List[ChunkBucket]:
        """Token-budget mixed-step chunk planning (vLLM/Sarathi-style).

        ``prefilling`` is ``[(slot, written, target)]`` in FCFS order:
        ``written`` counts tokens already in the slot's pages (cached prefix
        plus earlier chunks — the per-slot chunk cursor), ``target`` the
        prompt length the prefill must reach.  Each call packs up to
        ``budget`` actual chunk tokens (default ``max_prefill_tokens``;
        ``None`` = everything) and groups the chunks into power-of-two
        buckets like :meth:`plan`, so one engine step launches O(1) fused
        ``[n, pad_len]`` chunk prefills alongside its decode batch.

        Non-final chunks end on a page boundary, keeping every later chunk's
        start page-aligned (whole prefix pages for the kernel grid, clean
        scatter).  When the budget is smaller than the distance to the next
        boundary, the unaligned chunk is taken anyway — progress beats
        alignment, and the next call re-aligns.  The queue head always gets
        at least one token, so a budget below every chunk size still drains.
        """
        if budget is None:
            budget = self.max_prefill_tokens
        left = budget
        buckets: dict = {}
        for slot, written, target in prefilling:
            remaining = target - written
            if remaining <= 0:
                continue
            c = remaining if left is None else min(remaining, left)
            if c <= 0:
                break                       # budget exhausted: FCFS tail waits
            if c < remaining:
                aligned = ((written + c) // self.page_size) * self.page_size
                if aligned > written:
                    c = aligned - written
            blen = c if self.mode == "slotwise" else self.bucket_len(c)
            key = blen if self.mode == "bucketed" else (blen, slot)
            bkt = buckets.get(key)
            if bkt is None:
                bkt = buckets[key] = ChunkBucket(blen, [], [], [], [])
            bkt.slots.append(slot)
            bkt.starts.append(written)
            bkt.lens.append(c)
            bkt.final.append(written + c == target)
            self.counts["chunk_tokens"] += c
            if left is not None:
                left -= c
        if buckets:
            self.counts["chunk_rounds"] += 1
        return list(buckets.values())
