"""Admission scheduler: length-bucketed batched prefill planning.

The seed engine prefilled one request at a time (one compiled B=1 trace per
prompt length).  This scheduler instead admits *every* runnable queued
request in one engine step and groups them into **length buckets** (powers of
two of the page size), so each bucket compiles one joint ``[n, bucket_len]``
prefill and the number of distinct traces stays O(log max_seq) instead of
O(#prompt lengths).

Admission is strict FCFS: the queue head is admitted only if a free slot and
enough free pages exist; nothing behind it jumps ahead (no starvation).  A
``max_prefill_tokens`` budget bounds the padded tokens prefilled in a single
engine step — oversized backlogs are drained in chunks across steps so decode
latency of in-flight requests stays bounded.

**Page reservation** (``reservation=``): ``"lazy"`` (default) reserves only
the pages covering the prompt plus one decode token — the engine grows the
page table during decode and preempts on pool pressure, so pool occupancy
tracks *live* tokens and concurrency is bounded by real memory, not by the
worst case.  ``"worstcase"`` reserves ``prompt + max_tokens`` pages up front
(no growth or preemption ever needed) — kept as the benchmark baseline the
paper's single-A100 deployment story argues against.

**Watermark**: under lazy reservation the head is admitted only while
``free_pages >= need + reserve``, where ``reserve`` starts at the number of
already-decoding slots (passed by the engine) and rises by one per admitted
request.  Each live slot thus keeps about one page of growth headroom, so
preemption is the rare pressure-relief valve, not a steady-state tax.  The
reserve is waived when nothing is active (``reserve=0``) so an empty engine
can always admit its head and never deadlocks on its own watermark.

``mode="slotwise"`` degenerates to one request per bucket at its exact prompt
length — the seed engine's prefill strategy — kept as the benchmark baseline.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

from repro.serving.kv_cache import PagePool


@dataclasses.dataclass
class PrefillBucket:
    pad_len: int          # joint prefill length (tokens)
    reqs: list            # admitted Requests, FCFS order
    slots: List[int]      # slot id per request
    needs: List[int]      # pages reserved per request


class Scheduler:
    def __init__(self, *, page_size: int, max_seq: int,
                 max_prefill_tokens: Optional[int] = None,
                 mode: str = "bucketed", reservation: str = "lazy"):
        if mode not in ("bucketed", "slotwise"):
            raise ValueError(f"unknown prefill mode {mode!r}")
        if reservation not in ("lazy", "worstcase"):
            raise ValueError(f"unknown page reservation {reservation!r}")
        self.page_size = page_size
        self.max_seq = max_seq
        self.max_prefill_tokens = max_prefill_tokens
        self.mode = mode
        self.reservation = reservation

    def bucket_len(self, prompt_len: int) -> int:
        b = self.page_size
        while b < prompt_len:
            b *= 2
        return min(b, self.max_seq)

    def pages_needed(self, req, pool: PagePool) -> int:
        if self.reservation == "worstcase":
            want = min(len(req.prompt) + req.max_tokens, self.max_seq)
        else:
            # lazy: cover the prompt plus the first decode write only; the
            # engine grows the table page-by-page as decode proceeds
            want = min(len(req.prompt) + 1, self.max_seq)
        return pool.pages_needed(want)

    def plan(self, queue: Deque, free_slots: List[int], pool: PagePool,
             reserve: int = 0) -> List[PrefillBucket]:
        """Pop admissible requests off ``queue`` and bucket them.

        Reserves pages in ``pool`` for every admitted request (so a later
        bucket in the same step can't oversubscribe) and assigns slots.
        ``reserve`` is the admission watermark: free pages that must remain
        after each admit (one growth page per decoding slot — the engine
        passes its active-slot count, and each admission here adds one).
        """
        slots = deque(free_slots)
        budget = self.max_prefill_tokens
        buckets: dict[int, PrefillBucket] = {}
        spent = 0
        while queue and slots:
            req = queue[0]
            need = self.pages_needed(req, pool)
            if not pool.can_alloc(need + reserve):
                break                       # FCFS: head blocks the line
            blen = (len(req.prompt) if self.mode == "slotwise"
                    else self.bucket_len(len(req.prompt)))
            if budget is not None and spent and spent + blen > budget:
                break                       # chunk the backlog across steps
            queue.popleft()
            slot = slots.popleft()
            pool.alloc(slot, need)
            if self.reservation == "lazy":
                reserve += 1                # growth headroom for the new slot
            key = blen if self.mode == "bucketed" else (blen, slot)
            bkt = buckets.get(key)
            if bkt is None:
                bkt = buckets[key] = PrefillBucket(blen, [], [], [])
            bkt.reqs.append(req)
            bkt.slots.append(slot)
            bkt.needs.append(need)
            spent += blen
        return list(buckets.values())
