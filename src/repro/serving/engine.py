"""Continuous-batching serving engine with paged KV (the vLLM role, JAX-native).

Implements the paper's deployment story: an FP16/bf16 checkpoint is handed
in, SmoothQuant+ PTQ runs once (quantize-on-load), and requests are served
from a fixed-slot continuous batcher backed by a **paged KV cache**:

- the decode cache is a pool of fixed-size pages shared by all slots
  (``serving/kv_cache.py``); a host-side pager hands pages to requests on
  admission and reclaims them on finish, so cache memory tracks live tokens;
- arriving requests are admitted *in batches*: the scheduler
  (``serving/scheduler.py``) groups the runnable queue prefix into length
  buckets and each bucket prefills **jointly** — one compiled ``[n, blen]``
  trace per bucket instead of one B=1 trace per request — and the raw prefix
  KV is scattered straight into the pages (no per-slot cache merging);
- every engine step decodes ONE token for all active slots straight against
  the pages (W4A16 matmuls; on TPU the Pallas paged-attention kernel DMAs
  pages by block table inside the grid, on CPU/XLA the jnp gather reference
  runs — ``cfg.paged_attn_impl``), sampling **per-slot** temperatures;
- with ``cfg.kv_quant`` the pools are int8 + per-row f32 scales: prefix rows
  are quantized on admission, decode tokens before their pool write;
- finished slots free their pages immediately and are refilled from the
  queue — no head-of-line blocking, the continuous-batching win.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.models import api
from repro.serving import kv_cache as KV
from repro.serving.sampling import sample_per_slot
from repro.serving.scheduler import Scheduler


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [T] int32
    max_tokens: int = 16
    temperature: float = 0.0
    arrival_t: float = 0.0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None


@dataclasses.dataclass
class EngineStats:
    decoded_tokens: int = 0
    prefilled_tokens: int = 0
    steps: int = 0
    completed: int = 0
    prefill_batches: int = 0      # joint prefill launches (≤ admitted reqs)


class ServingEngine:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        batch_size: int = 8,
        max_seq: int = 256,
        page_size: int = 16,
        num_pages: Optional[int] = None,
        eos_id: int = 1,
        backend: str = "auto",
        seed: int = 0,
        max_prefill_tokens: Optional[int] = None,
        prefill_mode: str = "bucketed",
    ):
        ok, why = api.paged_supported(cfg)
        if not ok:
            raise NotImplementedError(f"paged serving: {why}")
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.PS = page_size
        self.P = -(-max_seq // page_size)          # pages per slot
        self.S = self.P * page_size                # max_seq rounded to pages
        self.eos = eos_id
        self.backend = backend
        self.key = jax.random.PRNGKey(seed)

        # +1: page 0 is the pager's trash page, never handed to a slot
        num_pages = num_pages or (batch_size * self.P + 1)
        if num_pages - 1 < self.P:
            # one max-size request must always be admittable once the pool
            # drains, or run_until_drained could spin on an empty batch
            raise ValueError(
                f"num_pages={num_pages} cannot hold one max_seq request "
                f"({self.P} pages of {page_size} tokens + trash page)")
        self.pager = KV.PagePool(num_pages, page_size, batch_size, self.P)
        self.pools = api.init_paged_cache(cfg, num_pages, page_size)
        self.sched = Scheduler(page_size=page_size, max_seq=self.S,
                               max_prefill_tokens=max_prefill_tokens,
                               mode=prefill_mode)

        self.slots: List[Optional[Request]] = [None] * batch_size
        self.pos = np.zeros(batch_size, np.int32)      # next position per slot
        self.last_tok = np.zeros(batch_size, np.int32)
        self.queue: deque[Request] = deque()
        self.stats = EngineStats()

        # donate the pools: the step's output cache aliases the input buffers
        # instead of allocating a second full pool every decoded token
        self._decode = jax.jit(
            lambda p, c, tok, pos, table: api.decode_paged_fn(
                p, {"token": tok, "position": pos}, c, table, cfg,
                backend=backend
            ),
            donate_argnums=(1,),
        )
        # joint length-bucketed prefill: raw prefix KV + per-row last logits.
        # jit re-specializes per (n, bucket_len); the scheduler's power-of-two
        # buckets keep that trace count O(log max_seq).
        self._prefill = jax.jit(
            lambda p, toks, last_idx: api.prefill_fn(
                p, {"tokens": toks}, cfg, self.S, backend=backend,
                last_idx=last_idx, raw_cache=True
            )
        )
        self._sample = jax.jit(sample_per_slot)

    # ------------------------------------------------------------- admin ---
    def submit(self, req: Request):
        if len(req.prompt) > self.S - 1:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens exceeds max_seq-1={self.S - 1}")
        req.arrival_t = req.arrival_t or time.perf_counter()
        self.queue.append(req)

    def _admit(self):
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return
        for bkt in self.sched.plan(self.queue, free, self.pager):
            n, blen = len(bkt.reqs), bkt.pad_len
            toks = np.zeros((n, blen), np.int32)
            lens = np.empty(n, np.int32)
            for r, req in enumerate(bkt.reqs):
                lens[r] = len(req.prompt)
                toks[r, : lens[r]] = req.prompt
            logits, raw = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(lens - 1))
            raw = {"layers": {k: v for k, v in raw["layers"].items()
                              if k != "lens"}}
            # int8 pools: quantize the raw prefix rows per-(position, head)
            # so the scatter below writes codes + scale leaves in one pass
            raw = api.quantize_raw_paged(raw, self.cfg)
            rows = self.pager.table()[bkt.slots]           # [n, P]
            page, off = KV.prefix_write_plan(lens, rows, self.PS, blen)
            self.pools = KV.write_prefix(
                self.pools, raw, jnp.asarray(page), jnp.asarray(off))
            self.key, sk = jax.random.split(self.key)
            temps = jnp.asarray([r.temperature for r in bkt.reqs], jnp.float32)
            firsts = np.asarray(self._sample(logits, sk, temps))
            now = time.perf_counter()
            for r, (slot, req) in enumerate(zip(bkt.slots, bkt.reqs)):
                first = int(firsts[r])
                req.output.append(first)
                req.first_token_t = now
                self.slots[slot] = req
                self.pos[slot] = lens[r]
                self.last_tok[slot] = first
                self.stats.prefilled_tokens += int(lens[r])
            self.stats.prefill_batches += 1

    # -------------------------------------------------------------- step ---
    def step(self) -> int:
        """Admit waiting requests, decode one token for all active slots.
        Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        # use-after-free tripwire: no active slot may point at the trash page
        KV.assert_live_tables(
            self.pager.table(), self.pos, self.PS,
            [s is not None for s in self.slots])
        tok = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos)
        table = jnp.asarray(self.pager.table())
        logits, self.pools = self._decode(self.params, self.pools, tok, pos, table)
        self.key, sk = jax.random.split(self.key)
        temps = jnp.asarray([
            self.slots[i].temperature if self.slots[i] else 0.0
            for i in range(self.B)
        ], jnp.float32)
        nxt = np.asarray(self._sample(logits, sk, temps))
        self.stats.steps += 1
        for i in active:
            req = self.slots[i]
            t = int(nxt[i])
            req.output.append(t)
            self.pos[i] += 1
            self.last_tok[i] = t
            self.stats.decoded_tokens += 1
            hit_len = len(req.output) >= req.max_tokens
            hit_eos = t == self.eos
            hit_cap = self.pos[i] >= self.S - 1
            if hit_len or hit_eos or hit_cap:
                req.done_t = time.perf_counter()
                self.stats.completed += 1
                self.slots[i] = None   # slot freed → continuous batching
                self.pos[i] = 0
                self.last_tok[i] = 0
                self.pager.free_slot(i)
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> EngineStats:
        while (self.queue or any(s is not None for s in self.slots)):
            if self.stats.steps >= max_steps:
                break
            self.step()
        return self.stats


def load_or_quantize(
    params_fp,
    cfg: ModelConfig,
    calibration_batches,
    qcfg: QuantConfig = QuantConfig(),
    *,
    artifact_dir=None,
    refresh: bool = False,
):
    """Load-*or*-quantize engine boot (quantize once, serve many).

    If ``artifact_dir`` holds a PTQ artifact whose config hash matches
    ``(cfg, qcfg)``, the quantized pytree + report deserialize straight from
    disk — zero calibration batches consumed, zero α-search steps.  Otherwise
    (no artifact, or a stale one from a changed config) the full SmoothQuant+
    recipe runs on ``params_fp`` and, when ``artifact_dir`` is given, the
    result is persisted for the next boot.  The hash covers the *configs*,
    not the weight values — after swapping checkpoints under an unchanged
    config, pass ``refresh=True`` (CLI: ``--ptq-refresh``) to force
    re-quantization."""
    from repro.core import apply as AP

    import zipfile

    if artifact_dir is not None and not refresh and AP.has_ptq(artifact_dir):
        try:
            return AP.load_ptq(artifact_dir, cfg, qcfg)
        except (ValueError, KeyError, OSError, zipfile.BadZipFile):
            # stale config hash, unknown format version, or a corrupt /
            # truncated meta.json / arrays.npz: every recoverable-by-
            # requantizing failure falls through to the full recipe (and
            # re-saves below) — unless there are no fp params to requantize
            # from (artifact-only warm boot), where hiding the load error
            # would just crash later inside calibration
            if params_fp is None:
                raise
    qp, rep = AP.smoothquant_plus(params_fp, cfg, calibration_batches, qcfg)
    if artifact_dir is not None:
        AP.save_ptq(artifact_dir, qp, rep, cfg, qcfg)
    return qp, rep


def load_and_quantize(
    params_fp, cfg: ModelConfig, calibration_batches, qcfg: QuantConfig = QuantConfig()
):
    """Quantize-on-load (paper §2.3): FP params in, W4A16 params out.
    Kept as the artifact-free entry; see :func:`load_or_quantize`."""
    return load_or_quantize(params_fp, cfg, calibration_batches, qcfg)
