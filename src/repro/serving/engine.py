"""Continuous-batching serving engine with paged KV (the vLLM role, JAX-native).

Implements the paper's deployment story: an FP16/bf16 checkpoint is handed
in, SmoothQuant+ PTQ runs once (quantize-on-load), and requests are served
from a fixed-slot continuous batcher backed by a **paged KV cache**:

- the decode cache is a pool of fixed-size pages shared by all slots
  (``serving/kv_cache.py``); a host-side pager hands pages to requests on
  admission and reclaims them on finish, so cache memory tracks live tokens;
- arriving requests are admitted *in batches*: the scheduler
  (``serving/scheduler.py``) assigns slots and pages to the runnable queue
  prefix; the prompt tokens themselves prefill in **chunks** under a token
  budget (``max_prefill_tokens``, vLLM/Sarathi-style **mixed steps**): every
  engine step packs up to the budget in prefill-chunk rows *and* decodes all
  active slots, so a long arriving prompt never stalls in-flight decodes.
  Chunks scatter their KV straight into the pages and attend the cached
  prefix + earlier chunks *through the page table* — the same paged
  machinery decode uses (Pallas chunked-prefill grid on TPU, jnp gather
  oracle on CPU — ``cfg.paged_attn_impl``); a slot's final chunk yields its
  first-token logits.  ``max_prefill_tokens=None`` prefills each prompt in
  one chunk (stop-the-world baseline);
- every engine step decodes ONE token for all slots past their prefill
  target straight against the pages (W4A16 matmuls), sampling **per-slot**
  temperatures;
- with ``cfg.kv_quant`` the pools are int8 + per-row f32 scales: prefix rows
  are quantized on admission, decode tokens before their pool write;
- finished slots free their pages immediately and are refilled from the
  queue — no head-of-line blocking, the continuous-batching win;
- **lazy page growth** (default): admission reserves only the pages covering
  the prompt + 1 decode token; the decode loop grows a slot's page table
  exactly when its write position crosses a page boundary, so pool occupancy
  tracks *live* tokens and concurrency is bounded by real memory, not the
  worst case (the single-A100 deployment headline of the paper).  On pool
  exhaustion the engine **preempts** the youngest active slot(s): their live
  *private* pool rows are swapped to a host buffer (raw codes + scales,
  bit-exact; the device→host copy is started asynchronously and only awaited
  at swap-in) and the request requeues at the *queue head* (FCFS preserved);
  it resumes by swap-in — page realloc + row scatter — never by
  re-prefilling.  An admission watermark (one free page per decoding slot)
  keeps preemption a rare pressure-relief valve.  ``reservation="worstcase"``
  restores the old up-front ``prompt + max_tokens`` reservation as the
  benchmark baseline.
- **shared-prefix KV cache** (``prefix_cache=True``): full prompt pages are
  block-hash-indexed (``serving/prefix_cache.py``); a request whose prompt
  extends a cached prefix *attaches* the matched pages (refcounted, shared,
  read-only — copy-on-write guards any write) and prefills **only its
  uncached suffix**, with prefill attention reading the cached prefix pages
  through the same paged machinery decode uses.  Finished slots index their
  generated full pages too, so multi-turn continuations match.  Unreferenced
  cached pages stay resident as an LRU pool reserve and are evicted exactly
  when an allocation needs them.  Shared pages are never swapped out with a
  preemption victim — swap-in re-acquires them.  Cache-hit requests emit
  greedy tokens identical to a cold run (asserted in tests/CI; note the
  identity is at the argmax level — a warm suffix prefill reads the prefix
  through the pools, so under ``kv_quant`` its logits match the cold run's
  only to within int8 quantization error, exactly like paged decode steps
  already do).

**State leaves** (``api.state_leaves``): a slot's device state is one or
more typed leaves, and every lifecycle primitive (admit, grow, preempt,
swap, resume, free) handles each kind by its own invariants:

- ``kv_pages`` — the paged attention pools above (every config has them;
  hybrid configs page only their shared-attention applications);
- ``fixed_rows`` — per-layer recurrent state rows ``[M, B, ...]`` for
  hybrid SSM configs (zamba2): O(1) per slot, never paged, zeroed at
  admission, round-tripped bit-exactly through the host swap buffer next
  to the KV rows under one combined CRC-32;
- ``shared_ro`` — read-only encoder K/V pages for enc-dec configs
  (whisper): allocated once per request in the pager's ``"enc"`` page
  group, deduplicated across requests by an exact-match (whole-sequence)
  prefix-cache index, never host-swapped — preemption detaches them under
  swap holds and resume reattaches.

The *token* prefix cache stays attention-only: KV pages cannot capture an
SSM boundary state and cross-attention depends on the encoder input, so
hybrid/enc-dec engines reject ``prefix_cache=True`` with a clear error
(enc-dec reuses the machinery for encoder pages instead).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.models import api
from repro.serving import kv_cache as KV
from repro.serving.faults import (FaultPlan, SimulatedDeviceError,
                                  TransientFault, corrupt_host_image)
from repro.serving.metrics import MetricsRegistry, format_pending
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import sample_per_slot
from repro.serving.scheduler import Scheduler
from repro.serving.trace import TraceRecorder

#: Terminal states every submitted request reaches exactly one of:
#:   completed — decoded its EOS token
#:   length    — hit max_tokens or the max_seq cache cap
#:   deadline  — expired a TTFT/total deadline (queued, swapped, or active)
#:   cancelled — explicit :meth:`ServingEngine.cancel`
#:   rejected  — refused at :meth:`ServingEngine.submit` (validation or
#:               bounded-queue backpressure); never entered the queue
#:   failed    — gave up after exhausting its fault-retry budget, or was
#:               quarantined by a non-strict engine (invariant violation /
#:               admission stall)
FINISH_REASONS = ("completed", "length", "deadline", "cancelled", "rejected",
                  "failed")


class UnsupportedModelError(NotImplementedError):
    """Raised at :class:`ServingEngine` construction for a config whose
    mixer/family has no paged serving path (e.g. a pure-RNN family with no
    fixed-rows adapter).  Subclasses :class:`NotImplementedError` so older
    callers that caught that still work; the point is failing *at engine
    build* with the reason, never mid-step with an ``AttributeError``."""


class RejectedRequest(ValueError):
    """Raised by :meth:`ServingEngine.submit` for *invalid* requests (empty
    prompt, non-positive ``max_tokens``, over-long prompt).  The request is
    marked terminal (``finish_reason="rejected"``, ``error`` says why) before
    the raise, so callers that catch still see a structured outcome.
    Bounded-queue backpressure does **not** raise — a full queue is an
    operational condition, not a caller bug — it returns ``False``."""


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [T] int32
    max_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0                # 0 disables (per-request, incl. first token)
    top_p: float = 1.0            # 1.0 disables
    arrival_t: float = 0.0
    deadline_s: Optional[float] = None       # total wall budget from arrival
    ttft_deadline_s: Optional[float] = None  # first-token budget from arrival
    frames: Optional[np.ndarray] = None      # [T_enc, d_model] (enc-dec only)
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    finish_reason: Optional[str] = None      # one of FINISH_REASONS when done
    error: Optional[str] = None              # detail for rejected/failed
    retries: int = 0              # transient-fault retries charged so far
    reprefills: int = 0           # swap-corruption re-prefills (budget: 1)
    submit_seq: int = -1          # FCFS age; youngest (max) is preempted first
    # swap-corruption replay: the token that must feed the next decode step
    # after the re-prefill lands (instead of sampling a duplicate), and how
    # many output tokens were folded into the prompt by the re-prefill
    _replay_tok: Optional[int] = None
    _gen_in_prompt: int = 0


@dataclasses.dataclass
class _SwapState:
    """Host-side image of a preempted slot: everything needed to resume it
    bit-exactly without re-prefilling.  Shared/cached pages are *not* part of
    the image — they stay resident in the pool under a swap hold and resume
    re-acquires them (``kept``); only private pages round-trip as rows.
    Fixed-rows slots (hybrid SSM) additionally carry their per-layer state
    rows in the same image (one combined checksum); enc-dec slots carry no
    encoder bytes at all — their read-only pages stay resident under swap
    holds (``enc_pages``) and resume reattaches them."""
    rows: Any                     # pytree [L, n_private, PS, ...] (or None)
    kept: List[Tuple[int, int]]   # (logical_idx, page) left resident
    private_lis: List[int]        # logical idxs of the swapped rows
    pos: int                      # next write position
    last_tok: int                 # token feeding the next decode step
    nbytes: int                   # KV swap-buffer bytes (stats)
    fbytes: int = 0               # fixed-rows (SSM state) bytes in the image
    on_host: bool = False         # rows materialized to numpy (device freed)
    checksum: Optional[int] = None  # CRC-32 of the host image (drain time)
    fixed_rows: Any = None        # pytree [M, 1, ...] SSM state (or None)
    enc_pages: Optional[List[int]] = None  # detached read-only enc pages
    enc_len: int = 0              # valid encoder rows to restore
    corrupted: bool = False       # injected rot already applied (flip once —
                                  # a second XOR would flip the byte *back*)


@dataclasses.dataclass
class EngineStats:
    decoded_tokens: int = 0
    prefilled_tokens: int = 0
    steps: int = 0
    completed: int = 0
    prefill_batches: int = 0      # joint prefill launches (≤ admitted reqs)
    preemptions: int = 0          # slots swapped out under pool pressure
    resumes: int = 0              # swapped slots re-admitted (swap-in)
    grown_pages: int = 0          # pages added by lazy decode growth
    swapped_out_bytes: int = 0    # pool bytes copied device -> host
    swapped_in_bytes: int = 0     # pool bytes copied host -> device
    swapped_fixed_bytes: int = 0  # of swapped_out: fixed-rows state bytes
    swapped_fixed_in_bytes: int = 0  # of swapped_in: fixed-rows state bytes
    enc_hits: int = 0             # admissions reusing cached encoder pages
    enc_encodes: int = 0          # admissions that ran the encoder
    idle_steps: int = 0           # drain iterations with nothing decodable
    max_active: int = 0           # peak concurrent decoding slots
    active_slot_steps: int = 0    # sum of active slots over steps (mean = /steps)
    # shared-prefix cache:
    admitted: int = 0             # requests admitted (incl. resumes? no: fresh)
    prefix_hits: int = 0          # admissions that matched >= 1 cached page
    prefix_matched_tokens: int = 0  # prompt tokens served from the cache
    pages_shared: int = 0         # page attachments (shared, not allocated)
    pages_inserted: int = 0       # pages newly indexed by the cache
    pages_evicted: int = 0        # unreferenced cached pages reclaimed (LRU)
    cow_copies: int = 0           # copy-on-write page duplications
    # request lifecycle / graceful degradation:
    rejected: int = 0             # refused at submit (validation/backpressure)
    expired: int = 0              # terminal by TTFT/total deadline
    cancelled: int = 0            # terminal by cancel()
    failed: int = 0               # terminal by retry exhaustion / quarantine
    retries: int = 0              # fault recoveries attempted (all kinds)
    faults_injected: int = 0      # FaultPlan fires observed (mirror of plan)


class ServingEngine:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        batch_size: int = 8,
        max_seq: int = 256,
        page_size: int = 16,
        num_pages: Optional[int] = None,
        eos_id: int = 1,
        backend: str = "auto",
        seed: int = 0,
        max_prefill_tokens: Optional[int] = None,
        prefill_mode: str = "bucketed",
        reservation: str = "lazy",
        prefix_cache: bool = False,
        max_queue: Optional[int] = None,
        strict: bool = True,
        fault_plan: Optional[FaultPlan] = None,
        retry_budget: int = 3,
        metrics: bool = True,
    ):
        ok, why = api.paged_supported(cfg)
        if not ok:
            raise UnsupportedModelError(f"paged serving: {why}")
        if cfg.act_quant not in ("a16", "a8_prefill"):
            raise ValueError(
                f"act_quant={cfg.act_quant!r}: expected 'a16' or 'a8_prefill' "
                "(a8_prefill routes prefill-chunk GEMMs on A8-eligible layers "
                "through the int8-activation kernel body; decode stays A16)")
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.PS = page_size
        self.P = -(-max_seq // page_size)          # pages per slot
        self.S = self.P * page_size                # max_seq rounded to pages
        self.eos = eos_id
        self.backend = backend
        self.key = jax.random.PRNGKey(seed)

        # which typed state leaves a slot of this config owns — every
        # lifecycle primitive below branches on these, nothing else does
        self.leaves = api.state_leaves(cfg)
        self.has_fixed = api.FIXED_ROWS in self.leaves
        self.has_enc = api.SHARED_RO in self.leaves

        # +1: page 0 is the pager's trash page, never handed to a slot.
        # Enc-dec slots additionally page their encoder K/V ("enc" group),
        # so the default pool and the one-request floor both double.
        slot_pages = self.P * (2 if self.has_enc else 1)
        num_pages = num_pages or (batch_size * slot_pages + 1)
        if num_pages - 1 < slot_pages:
            # one max-size request must always be admittable once the pool
            # drains, or run_until_drained could spin on an empty batch
            raise ValueError(
                f"num_pages={num_pages} cannot hold one max_seq request "
                f"({slot_pages} pages of {page_size} tokens + trash page)")
        groups = ("kv", "enc") if self.has_enc else ("kv",)
        self.pager = KV.PagePool(num_pages, page_size, batch_size, self.P,
                                 groups=groups)
        if prefix_cache and (self.has_fixed or self.has_enc):
            raise ValueError(
                "prefix_cache=True is attention-only: KV pages cannot "
                "capture an SSM boundary state (hybrid) and cross-attention "
                "depends on the encoder input (enc-dec); enc-dec engines "
                "deduplicate encoder pages automatically instead")
        self.cache: Optional[PrefixCache] = (
            PrefixCache(self.pager, page_size,
                        mode=f"kvq={int(bool(cfg.kv_quant))}")
            if prefix_cache else None)
        # exact-match index over read-only encoder pages (same machinery,
        # whole-sequence keys): identical frames across requests share one
        # resident page set.  Registers as the pool's (only) evictor.
        self.enc_cache: Optional[PrefixCache] = (
            PrefixCache(self.pager, page_size, mode="enc")
            if self.has_enc else None)
        self.pools = api.init_paged_cache(cfg, num_pages, page_size)
        self.fixed = (api.init_fixed_state(cfg, batch_size)
                      if self.has_fixed else None)
        self.enc_len = np.zeros(batch_size, np.int32)   # valid enc rows/slot
        self.reservation = reservation
        self.sched = Scheduler(page_size=page_size, max_seq=self.S,
                               max_prefill_tokens=max_prefill_tokens,
                               mode=prefill_mode, reservation=reservation)

        self.slots: List[Optional[Request]] = [None] * batch_size
        self.pos = np.zeros(batch_size, np.int32)      # next position per slot
        self.last_tok = np.zeros(batch_size, np.int32)
        # prompt length each slot must reach before decoding: a slot is
        # *prefilling* while pos < pref_target (its chunk cursor is pos) and
        # *decoding* once pos >= pref_target
        self.pref_target = np.zeros(batch_size, np.int32)
        self.queue: deque[Request] = deque()
        self.stats = EngineStats()
        self._swapped: dict[int, _SwapState] = {}   # submit_seq -> swap image
        self._next_seq = 0                             # FCFS submission clock

        # ----- request lifecycle / graceful degradation -----
        # max_queue bounds the waiting line: submit() rejects (structured,
        # finish_reason="rejected") instead of growing without bound.  strict
        # governs the abnormal paths: True (default) keeps every invariant
        # violation / admission stall a hard raise (what tests want); False
        # quarantines the offending request (finish_reason="failed") and
        # keeps serving everyone else (what production wants).
        self.max_queue = max_queue
        self.strict = strict
        self.retry_budget = retry_budget
        self.faults = fault_plan
        self.pager.faults = fault_plan
        if self.cache is not None:
            self.cache.faults = fault_plan
        if self.enc_cache is not None:
            self.enc_cache.faults = fault_plan
        self._clock = time.perf_counter     # swappable in tests (deadlines)
        self._step_idx = 0                  # all engine steps (idle included)
        self._retry_pending = False         # last step skipped work on a fault

        # ----- observability: metrics registry + lifecycle/journal trace ---
        # One clock rules everything: the registry and recorder late-bind
        # to self._clock, so a test that swaps the engine clock gets
        # deterministic histograms, timelines, and trace exports for free.
        # All recording is host-side bookkeeping (ints, floats, deques) —
        # it never touches jax, the pools, or the RNG stream, which is what
        # makes the metrics-on/off greedy token-identity guarantee hold.
        # ``metrics=False`` strips every observe from the hot path (the
        # overhead-benchmark baseline); the registry still exists so
        # metrics_snapshot() stays well-formed (empty histograms).
        self._obs = metrics
        self.metrics = MetricsRegistry(clock=lambda: self._clock())
        self.trace = TraceRecorder(lambda: self._clock(), enabled=metrics)
        self._h_ttft = self.metrics.histogram(
            "ttft_s", "submit -> first token (engine-internal)")
        # ITL gaps cluster within a decade (one step vs a stalled step), so
        # this histogram gets 4x the bucket resolution (~5%/bucket) — the
        # mixed-prefill benchmark discriminates stalls through it
        self._h_itl = self.metrics.histogram(
            "itl_s", "gap between consecutive tokens of one request",
            per_decade=48)
        self._h_e2e = self.metrics.histogram(
            "e2e_s", "submit -> terminal state")
        self._h_qwait = self.metrics.histogram(
            "queue_wait_s", "submit -> first admission to a slot")
        self._h_swap = self.metrics.histogram(
            "swap_stall_s", "preempt (swap-out) -> swap-in resume")
        self._fault_ctr = self.metrics.counter(
            "faults_fired_total", "fault-plan probes fired, by site")
        if fault_plan is not None:
            fault_plan.sink = self._on_fault
        self._last_dec: List[int] = []      # decode slots of the last step

        # donate the pools: the step's output cache aliases the input buffers
        # instead of allocating a second full pool every decoded token.
        # The launch signature follows the config's state leaves — hybrid
        # threads the fixed-rows tree (donated too) plus an active mask,
        # enc-dec threads the encoder page table + valid lengths.
        if self.has_fixed:
            self._decode = jax.jit(
                lambda p, c, fixed, tok, pos, table, active:
                    api.decode_paged_fn(
                        p, {"token": tok, "position": pos}, c, table, cfg,
                        backend=backend, fixed=fixed, active=active),
                donate_argnums=(1, 2),
            )
        elif self.has_enc:
            self._decode = jax.jit(
                lambda p, c, tok, pos, table, enc_table, enc_len:
                    api.decode_paged_fn(
                        p, {"token": tok, "position": pos}, c, table, cfg,
                        backend=backend, enc_table=enc_table,
                        enc_len=enc_len),
                donate_argnums=(1,),
            )
            self._encode = jax.jit(
                lambda p, fr: api.encode_kv_fn(p, fr, cfg, backend=backend))
        else:
            self._decode = jax.jit(
                lambda p, c, tok, pos, table: api.decode_paged_fn(
                    p, {"token": tok, "position": pos}, c, table, cfg,
                    backend=backend
                ),
                donate_argnums=(1,),
            )
        # joint length-bucketed chunk prefill: each row is one [blen] prompt
        # chunk at logical positions start_len[r] + t; KV scatters into the
        # pages and attention reads every earlier token (cached prefix and
        # prior chunks alike) through the table.  jit re-specializes per
        # (n, bucket_len); the scheduler's power-of-two buckets keep that
        # trace count O(log max_seq).  Pools donated: the chunk's output
        # cache aliases the input buffers.
        if self.has_fixed:
            self._prefill_chunk = jax.jit(
                lambda p, toks, last_idx, starts, lens, table, pools, fixed,
                       slots:
                    api.prefill_chunk_fn(
                        p, {"tokens": toks}, pools, table, starts, lens, cfg,
                        backend=backend, last_idx=last_idx, fixed=fixed,
                        slots=slots),
                donate_argnums=(6, 7),
            )
            # fresh admission starts from zero SSM state (the previous
            # occupant's rows are stale, not trash-maskable like KV pages)
            self._fixed_zero = jax.jit(
                lambda f, slot: jax.tree.map(
                    lambda a: a.at[:, slot].set(0), f),
                donate_argnums=(0,),
            )
        elif self.has_enc:
            self._prefill_chunk = jax.jit(
                lambda p, toks, last_idx, starts, lens, table, pools,
                       enc_table, enc_len:
                    api.prefill_chunk_fn(
                        p, {"tokens": toks}, pools, table, starts, lens, cfg,
                        backend=backend, last_idx=last_idx,
                        enc_table=enc_table, enc_len=enc_len),
                donate_argnums=(6,),
            )
        else:
            self._prefill_chunk = jax.jit(
                lambda p, toks, last_idx, starts, lens, table, pools:
                    api.prefill_chunk_fn(
                        p, {"tokens": toks}, pools, table, starts, lens, cfg,
                        backend=backend, last_idx=last_idx
                    ),
                donate_argnums=(6,),
            )
        self._sample = jax.jit(sample_per_slot)

    # ----------------------------------------------------- observability ---
    def _on_fault(self, site: str) -> None:
        """FaultPlan sink: every probe that fires lands as a labeled counter
        increment + a journal mark, so chaos runs can reconcile the plan's
        own ``injected`` tally against engine-side counters."""
        if self._obs:
            self._fault_ctr.inc(site=site)
            self.trace.note_fault(site)

    def _note_finish(self, req: Request) -> None:
        """Close a request's timeline (any terminal reason except rejected —
        a rejected request never entered the queue and has no timeline)."""
        t = req.done_t
        tl = self.trace.timeline(req.uid)
        tl.add(t, "finish", reason=req.finish_reason)
        tl.finish_t = t
        self._h_e2e.observe(t - req.arrival_t)
        self.trace.finish(req.uid)

    # ------------------------------------------------------------- admin ---
    def _reject(self, req: Request, why: str, *, raise_: bool) -> bool:
        """Structured rejection: the request turns terminal *now* — it never
        enters the queue, never holds a page, and its ``finish_reason``
        tells the caller exactly why."""
        req.finish_reason = "rejected"
        req.error = why
        req.done_t = self._clock()
        self.stats.rejected += 1
        if raise_:
            raise RejectedRequest(why)
        return False

    def submit(self, req: Request) -> bool:
        """Enqueue ``req``; returns True on admission to the queue.

        Invalid requests (empty prompt, ``max_tokens <= 0``, prompt longer
        than ``max_seq - 1``) raise :class:`RejectedRequest` — a caller bug.
        A full bounded queue (``max_queue``) rejects *without* raising and
        returns False — backpressure is an operational signal the caller
        handles by retrying later or shedding load.  Both paths mark the
        request terminal with ``finish_reason="rejected"``.
        """
        if len(req.prompt) == 0:
            return self._reject(req, "empty prompt", raise_=True)
        if req.max_tokens <= 0:
            return self._reject(
                req, f"max_tokens must be >= 1, got {req.max_tokens}",
                raise_=True)
        if len(req.prompt) > self.S - 1:
            return self._reject(
                req, f"prompt of {len(req.prompt)} tokens exceeds "
                     f"max_seq-1={self.S - 1}", raise_=True)
        if self.has_enc:
            if req.frames is None:
                return self._reject(
                    req, "enc-dec config: request must carry encoder frames",
                    raise_=True)
            fr = np.asarray(req.frames)
            if fr.ndim != 2 or fr.shape[0] < 1 \
                    or fr.shape[1] != self.cfg.d_model:
                return self._reject(
                    req, f"frames must be [T_enc>=1, {self.cfg.d_model}], "
                         f"got {fr.shape}", raise_=True)
            if fr.shape[0] > self.S:
                return self._reject(
                    req, f"{fr.shape[0]} encoder frames exceed the "
                         f"{self.S}-row page budget", raise_=True)
            req.frames = fr
        elif req.frames is not None:
            return self._reject(
                req, "frames on a decoder-only config", raise_=True)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            return self._reject(
                req, f"queue full ({self.max_queue} waiting)", raise_=False)
        req.arrival_t = req.arrival_t or self._clock()
        req.submit_seq = self._next_seq
        self._next_seq += 1
        self.queue.append(req)
        if self._obs:
            tl = self.trace.timeline(req.uid)
            tl.submit_t = req.arrival_t
            tl.add(req.arrival_t, "submit", prompt=len(req.prompt))
        return True

    def cancel(self, uid: int) -> bool:
        """Cancel the request with ``uid`` wherever it lives — waiting in the
        queue, swapped out, or actively prefilling/decoding.  Its pages (and
        any swap-hold pins) free immediately; tokens already generated stay
        on ``req.output``.  Returns False when no live request has ``uid``
        (already finished, or never submitted)."""
        for r in list(self.queue):
            if r.uid == uid:
                self.queue.remove(r)
                self._finish_abnormal(r, "cancelled")
                return True
        for i, r in enumerate(self.slots):
            if r is not None and r.uid == uid:
                self._evict_slot(i, "cancelled")
                return True
        return False

    # ------------------------------------------- terminal abnormal paths ---
    def _finish_abnormal(self, req: Request, reason: str,
                         error: Optional[str] = None) -> None:
        """Turn a request terminal off the happy path (deadline / cancelled /
        failed).  Cleans up any swap state it holds: the host image is
        dropped and every kept-page swap hold released, so the pool sees the
        pages again immediately."""
        st = self._swapped.pop(req.submit_seq, None)
        if st is not None:
            for _, p in st.kept:
                self.pager.drop_hold(p)
            if st.enc_pages:
                self.pager.drop_group_holds(st.enc_pages)
        req.finish_reason = reason
        req.error = error
        req.done_t = self._clock()
        counter = {"deadline": "expired", "cancelled": "cancelled",
                   "failed": "failed"}[reason]
        setattr(self.stats, counter, getattr(self.stats, counter) + 1)
        if self._obs:
            self._note_finish(req)

    def _evict_slot(self, slot: int, reason: str,
                    error: Optional[str] = None) -> None:
        """Terminate the request occupying ``slot`` abnormally and free the
        slot + its pages — the degradation primitive behind deadlines,
        cancellation, and quarantine."""
        req = self.slots[slot]
        self.slots[slot] = None
        self.pos[slot] = 0
        self.last_tok[slot] = 0
        self.pref_target[slot] = 0
        self.enc_len[slot] = 0
        self.pager.free_slot(slot)
        self._finish_abnormal(req, reason, error)

    def _deadline_hit(self, req: Request, now: float) -> bool:
        age = now - req.arrival_t
        if req.deadline_s is not None and age > req.deadline_s:
            return True
        return (req.ttft_deadline_s is not None
                and req.first_token_t is None
                and age > req.ttft_deadline_s)

    def _expire_deadlines(self) -> None:
        """Per-request TTFT/total deadlines, checked every step: an expired
        request turns terminal (``finish_reason="deadline"``) with its pages
        freed — wherever it is (queued, swapped out, prefilling, decoding) —
        instead of burning compute on an answer nobody is waiting for."""
        if not any(r.deadline_s is not None or r.ttft_deadline_s is not None
                   for r in list(self.queue) + self.slots if r is not None):
            return
        now = self._clock()
        for r in [r for r in self.queue if self._deadline_hit(r, now)]:
            self.queue.remove(r)
            self._finish_abnormal(r, "deadline")
        for i in self._active_slots():
            if self._deadline_hit(self.slots[i], now):
                self._evict_slot(i, "deadline")

    def _active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def _sample_reqs(self, logits, sk, temps, reqs):
        """Per-row sampling for a list of Requests (None for idle rows).
        The per-row top-k/top-p arrays are only passed when some request in
        the batch actually filters; the all-default call hits
        ``sample_per_slot``'s static fast path, keeping the two full-vocab
        sorts out of the compiled greedy/temperature-only decode step."""
        if not any(r is not None and (r.top_k or r.top_p < 1.0) for r in reqs):
            return self._sample(logits, sk, temps)
        tks = jnp.asarray([r.top_k if r else 0 for r in reqs], jnp.int32)
        tps = jnp.asarray([r.top_p if r else 1.0 for r in reqs], jnp.float32)
        return self._sample(logits, sk, temps, tks, tps)

    # -------------------------------------------------- prefix-cache glue --
    def _written_tokens(self, slot: int) -> np.ndarray:
        """Token ids at every written position of ``slot`` (prompt followed
        by the generated tokens whose KV has landed in the pages)."""
        req = self.slots[slot]
        n_gen = int(self.pos[slot]) - len(req.prompt)
        if n_gen <= 0:
            return np.asarray(req.prompt, np.int32)
        # after a swap-corruption re-prefill the first _gen_in_prompt output
        # tokens already live inside req.prompt; only the rest are "written
        # beyond the prompt"
        off = req._gen_in_prompt
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(req.output[off:off + n_gen],
                                          np.int32)])

    def _cache_insert_slot(self, slot: int) -> None:
        """Index every full written page of ``slot`` (idempotent).  The
        scheduler memoized the prompt's chain hashes on the request; only
        pages of generated tokens hash fresh here."""
        toks = self._written_tokens(slot)
        req = self.slots[slot]
        head = getattr(req, "_block_hashes", ())
        hashes = self.cache.block_hashes(toks, head=head)
        self.stats.pages_inserted += self.cache.insert(
            toks, self.pager.slot_pages(slot), len(toks) // self.PS,
            hashes=hashes)

    # ---------------------------------------------------- swap-out / -in ---
    def _kv_pools(self):
        """The KV-pages subtree of the device pools — what page-granular
        swap gathers/scatters.  Enc-dec pools also hold the read-only
        encoder pool, which must never ride a KV swap image (its pages are
        detached/reattached, the data never moves)."""
        return self.pools["layers"] if self.has_enc else self.pools

    def _set_kv_pools(self, new) -> None:
        if self.has_enc:
            self.pools = {**self.pools, "layers": new}
        else:
            self.pools = new

    def _preempt(self, slot: int) -> None:
        """Swap ``slot`` out and requeue its request at the queue *head* (it
        was admitted before anything still queued, so FCFS order is
        preserved).  Only the slot's *private* pages round-trip through the
        host buffer — pages shared with other slots or resident in the prefix
        cache stay in the pool under a swap hold (they were read-only full
        pages anyway) and resume re-acquires them.  The swap buffer holds the
        private pool rows verbatim — fp16 K/V or int8 codes + f32 scale
        leaves — so resume is bit-exact and preemption is a pure scheduling
        effect.  The device→host copy is kicked off asynchronously and
        overlaps the following decode step, after which the rows are
        materialized to host and the device-side gather buffer dropped
        (:meth:`_drain_swap_buffers`).

        Non-KV leaves ride the same preemption: a hybrid slot's fixed-rows
        state is gathered into the image next to the KV rows (same async
        copy, one combined checksum); an enc-dec slot's read-only encoder
        pages never leave the device — they detach under swap holds and
        resume reattaches them."""
        req = self.slots[slot]
        kept, private = self.pager.split_for_swap(slot)
        rows, nbytes = None, 0
        if private:
            rows = api.gather_pool_rows(
                self._kv_pools(),
                jnp.asarray([p for _, p in private], jnp.int32))
            # start the device->host transfer without blocking the step loop
            jax.tree.map(lambda a: a.copy_to_host_async(), rows)
            nbytes = sum(a.nbytes for a in jax.tree.leaves(rows))
        frows, fbytes = None, 0
        if self.has_fixed:
            frows = api.gather_pool_rows(
                self.fixed, jnp.asarray([slot], jnp.int32))
            jax.tree.map(lambda a: a.copy_to_host_async(), frows)
            fbytes = sum(a.nbytes for a in jax.tree.leaves(frows))
        enc_pages, enc_len = None, 0
        if self.has_enc:
            enc_pages = self.pager.detach_group(slot, "enc")
            enc_len = int(self.enc_len[slot])
            self.enc_len[slot] = 0
        self.pager.swap_out(slot, (kept, private))
        self._swapped[req.submit_seq] = _SwapState(
            rows=rows, kept=kept, private_lis=[li for li, _ in private],
            pos=int(self.pos[slot]), last_tok=int(self.last_tok[slot]),
            nbytes=nbytes, fbytes=fbytes, fixed_rows=frows,
            enc_pages=enc_pages, enc_len=enc_len)
        self.queue.appendleft(req)
        self.slots[slot] = None
        self.pos[slot] = 0
        self.last_tok[slot] = 0
        self.pref_target[slot] = 0
        self.stats.preemptions += 1
        # KV and fixed-state bytes are accounted symmetrically with
        # _resume: both sides charge nbytes + fbytes, so a drained engine
        # always shows swapped_out_bytes == swapped_in_bytes
        self.stats.swapped_out_bytes += nbytes + fbytes
        self.stats.swapped_fixed_bytes += fbytes
        if self._obs:
            t = self.trace.event(req.uid, "preempt", slot=slot,
                                 bytes=nbytes + fbytes)
            self.trace.timeline(req.uid).preempt_t = t
            self.trace.note_preempt(req.uid, slot)

    def _resume(self, slot: int, req: Request) -> None:
        """Swap a preempted request back in: re-acquire its held shared
        pages, realloc the private ones, scatter the host rows into them
        (first touch of the async swap buffer), restore the decode cursor."""
        st = self._swapped.pop(req.submit_seq)
        fresh = self.pager.swap_in(slot, st.kept, st.private_lis)
        if st.rows is not None:
            rows = jax.device_get(st.rows)     # no-op once drained to host
            self._set_kv_pools(api.scatter_pool_rows(
                self._kv_pools(), rows, jnp.asarray(fresh, jnp.int32)))
        if st.fixed_rows is not None:
            # same dtypes both ways (f32 h, model-dtype conv tails), so the
            # restored recurrent state is bit-identical to the preempted one
            self.fixed = api.scatter_pool_rows(
                self.fixed, jax.device_get(st.fixed_rows),
                jnp.asarray([slot], jnp.int32))
        if st.enc_pages is not None:
            self.pager.reattach_group(slot, "enc", st.enc_pages)
            self.enc_len[slot] = st.enc_len
        self.slots[slot] = req
        self.pos[slot] = st.pos
        self.last_tok[slot] = st.last_tok
        # a slot preempted mid-prefill resumes mid-prefill: its chunk cursor
        # (pos) restores below pref_target and chunking picks it back up
        self.pref_target[slot] = len(req.prompt)
        self.stats.resumes += 1
        self.stats.swapped_in_bytes += st.nbytes + st.fbytes
        self.stats.swapped_fixed_in_bytes += st.fbytes
        if self._obs:
            t = self.trace.event(req.uid, "swap_in", slot=slot,
                                 bytes=st.nbytes + st.fbytes)
            tl = self.trace.timeline(req.uid)
            if tl.preempt_t is not None:
                self._h_swap.observe(t - tl.preempt_t)
                tl.preempt_t = None
            self.trace.note_resume(req.uid, slot)

    def _charge_retry(self, slot: int, why: str) -> None:
        """Charge one fault retry against the request in ``slot``; exhausting
        the budget turns it terminal (``failed``) instead of livelocking."""
        req = self.slots[slot]
        req.retries += 1
        self.stats.retries += 1
        self._retry_pending = True
        if self._obs:
            self.trace.event(req.uid, "retry", slot=slot, why=why,
                             n=req.retries)
        if req.retries > self.retry_budget:
            self._evict_slot(
                slot, "failed",
                f"fault-retry budget exhausted ({self.retry_budget}): {why}")

    def _ensure_pages(self) -> set:
        """Lazy growth: every active slot must own the pages covering its next
        write position before the decode step runs.  Oldest slots are grown
        first; on pool exhaustion the *youngest* active slot is preempted
        (repeatedly, until the grow fits) — possibly the growing slot itself,
        which then simply leaves the batch until pages free up.

        Returns the set of slots whose growth hit an injected transient
        fault this step: they must sit out the decode launch (their table
        doesn't cover the write position) and retry next step, each attempt
        charged against the request's bounded retry budget."""
        stalled: set = set()
        if self.reservation != "lazy":
            return stalled             # worst-case reservation never grows
        for i in sorted(self._active_slots(),
                        key=lambda j: self.slots[j].submit_seq):
            while self.slots[i] is not None:
                need = int(self.pos[i]) // self.PS + 1
                if len(self.pager.slot_pages(i)) >= need:
                    break
                if self.pager.can_alloc(1):
                    try:
                        self.pager.grow(i, 1)
                        self.stats.grown_pages += 1
                    except TransientFault as e:
                        self._charge_retry(i, str(e))
                        stalled.add(i)
                        break
                else:
                    victim = max(self._active_slots(),
                                 key=lambda j: self.slots[j].submit_seq)
                    self._preempt(victim)
        return stalled

    def _verify_swap_image(self, req: Request) -> bool:
        """Checksum-verify a drained swap image before its rows ever reach
        the pool.  On mismatch the image is discarded (holds released) and
        the request converts to a **re-prefill**: its written tokens (prompt
        + generated) become the prefill target, and the decode resumes from
        the restored last token — degraded (recompute) but never poisoned.
        Returns False when the request must not resume by swap-in."""
        st = self._swapped[req.submit_seq]
        has_img = st.rows is not None or st.fixed_rows is not None
        if (not has_img or not st.on_host or st.checksum is None
                or api.swap_image_checksum(
                    {"kv": st.rows, "fixed": st.fixed_rows}) == st.checksum):
            return True
        # poisoned host buffer detected — never scatter it (KV rows and
        # fixed state rows alike; the SSM state re-derives from the token
        # replay exactly like the KV pages do)
        self._swapped.pop(req.submit_seq)
        for _, p in st.kept:
            self.pager.drop_hold(p)
        if st.enc_pages:
            # the detached encoder pages are clean (they never entered the
            # host image) and stay indexed — dropping the holds makes them
            # evictable, and the re-admission's exact-match lookup normally
            # re-attaches them without re-encoding
            self.pager.drop_group_holds(st.enc_pages)
        req.reprefills += 1
        self.stats.retries += 1
        self._retry_pending = True
        if req.reprefills > 1:      # re-prefill at most once
            self.queue.remove(req)
            self._finish_abnormal(
                req, "failed", "swap image corrupted twice — giving up")
            return False
        n_gen = st.pos - len(req.prompt)
        if n_gen > 0:
            # replay prompt + generated tokens through prefill; the next
            # decode must feed the already-sampled last token, not sample a
            # duplicate from the final chunk's logits
            req._replay_tok = st.last_tok
            off = req._gen_in_prompt
            req.prompt = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.output[off:off + n_gen], np.int32)])
            req._gen_in_prompt = off + n_gen
            if hasattr(req, "_block_hashes"):
                del req._block_hashes   # memoized over the old prompt
        # req stays at the queue head, now unswapped: plan() admits it as a
        # fresh prefill (FCFS preserved — it was admitted first)
        return False

    def _admit(self):
        free = [i for i, s in enumerate(self.slots) if s is None]
        # preempted requests sit at the queue head (FCFS); resume them by
        # swap-in before planning fresh prefills — and if the head can't be
        # resumed yet, nothing behind it may jump the line
        while self.queue and self.queue[0].submit_seq in self._swapped:
            if not free:
                return
            if not self._verify_swap_image(self.queue[0]):
                break               # corrupted: head re-prefills (or failed)
            st = self._swapped[self.queue[0].submit_seq]
            reserve = self.B - len(free)          # watermark: active slots
            if not self.pager.can_alloc(len(st.private_lis) + reserve):
                return
            self._resume(free.pop(0), self.queue.popleft())
        if not free or not self.queue:
            return
        # the planner must never see a swap-resumable request — they resume
        # by swap-in only.  Normally they form a queue prefix fully handled
        # above, but a corruption-converted head leaves its still-swapped
        # siblings *behind* a plannable request: pull them out for the
        # duration of the plan and splice them back in FCFS order after.
        parked = [r for r in self.queue if r.submit_seq in self._swapped]
        for r in parked:
            self.queue.remove(r)
        reserve = (self.B - len(free)) if self.reservation == "lazy" else 0
        for bkt in self.sched.plan(self.queue, free, self.pager, reserve,
                                   self.cache):
            pfx = np.asarray(bkt.prefix_lens, np.int32)
            # COW first: a page-aligned full match re-prefills the last
            # prompt token into a private copy of the final matched page,
            # so the copies must exist before any chunk reads/writes them.
            # The planner left a hold on each src pinning it against reuse
            # until its rows are duplicated here (one batched dispatch).
            pairs = [p for p in bkt.cow if p is not None]
            if pairs:
                self.pools = api.copy_pool_page(
                    self.pools,
                    jnp.asarray([s for s, _ in pairs], jnp.int32),
                    jnp.asarray([d for _, d in pairs], jnp.int32))
                for src, _ in pairs:
                    self.pager.drop_hold(src)
                self.stats.cow_copies += len(pairs)
            # admission stops here: the slot's chunk cursor starts at the
            # cached-prefix length and the prompt tokens themselves prefill
            # in budgeted chunks (:meth:`_prefill_chunks`), interleaved with
            # decode steps
            for r, (slot, req) in enumerate(zip(bkt.slots, bkt.reqs)):
                self.slots[slot] = req
                self.pos[slot] = int(pfx[r])
                self.pref_target[slot] = len(req.prompt)
                self.last_tok[slot] = 0
                if self.has_fixed:
                    # the previous occupant's recurrent state is stale, not
                    # trash-maskable like KV pages — zero it before chunk 1
                    self.fixed = self._fixed_zero(
                        self.fixed, jnp.asarray(slot, jnp.int32))
                if self.has_enc:
                    self._admit_enc(slot, req)
                self.stats.admitted += 1
                self.stats.prefix_matched_tokens += int(pfx[r])
                self.stats.prefix_hits += int(pfx[r] > 0)
                self.stats.pages_shared += bkt.shared[r]
                if self._obs:
                    t = self.trace.event(req.uid, "admit", slot=slot,
                                         cached_tokens=int(pfx[r]))
                    tl = self.trace.timeline(req.uid)
                    if tl.admit_t is None:   # first admission = queue wait
                        tl.admit_t = t
                        self._h_qwait.observe(t - req.arrival_t)
        if self.sched.last_plan_aborted and self.queue:
            # a transient grow fault aborted the plan mid-admission; the
            # scheduler rolled the victim back to the queue head.  Charge its
            # bounded retry budget so an endlessly-faulting admission fails
            # the request instead of livelocking the drain loop.
            head = self.queue[0]
            head.retries += 1
            self.stats.retries += 1
            self._retry_pending = True
            if head.retries > self.retry_budget:
                self.queue.popleft()
                self._finish_abnormal(
                    head, "failed",
                    f"admission fault-retry budget exhausted "
                    f"({self.retry_budget})")
        if parked:
            merged = sorted(list(self.queue) + parked,
                            key=lambda r: r.submit_seq)
            self.queue.clear()
            self.queue.extend(merged)

    def _admit_enc(self, slot: int, req: Request) -> None:
        """Fill ``slot``'s encoder pages at admission: the scheduler already
        grew the fresh page set ("enc" group, charged in its plan), so
        either an exact-match cache hit swaps them for the shared resident
        copy (free fresh, attach cached — the conservative charge is
        returned here), or the encoder runs once and its K/V rows scatter
        into the fresh pages, which are then indexed for the next request
        with identical frames."""
        fr = req.frames
        npg = self.pager.pages_needed(len(fr))
        hashes = getattr(req, "_enc_hashes", None)
        if hashes is None:
            hashes = self.enc_cache.data_hashes(fr, npg)
            req._enc_hashes = hashes
        cached = self.enc_cache.match_exact(hashes)
        if cached:
            self.pager.free_group(slot, "enc")
            self.pager.attach(slot, cached, group="enc")
            self.stats.enc_hits += 1
            self.stats.pages_shared += len(cached)
        else:
            pages = self.pager.slot_pages(slot, "enc")
            kv = self._encode(self.params,
                              jnp.asarray(fr, self.cfg.jdtype)[None])
            s = int(kv["xk"].shape[2])
            pad = npg * self.PS - s
            rows = jax.tree.map(
                lambda a: jnp.pad(a[:, 0], ((0, 0), (0, pad), (0, 0),
                                            (0, 0)))
                             .reshape(a.shape[0], npg, self.PS,
                                      a.shape[3], a.shape[4]),
                kv)
            self.pools = {**self.pools, "enc": api.scatter_pool_rows(
                self.pools["enc"], rows, jnp.asarray(pages, jnp.int32))}
            self.enc_cache.insert_exact(hashes, pages)
            self.stats.enc_encodes += 1
        self.enc_len[slot] = len(fr)

    def _prefill_chunks(self) -> int:
        """Advance every prefilling slot by its scheduled chunk: pack up to
        ``max_prefill_tokens`` chunk rows into power-of-two buckets (FCFS by
        admission age), launch one fused ``[n, blen]`` chunk prefill per
        bucket, and sample the first token on rows whose chunk completes the
        prompt.  Returns the number of chunk rows worked."""
        items = [(i, int(self.pos[i]), int(self.pref_target[i]))
                 for i in sorted(
                     (j for j in self._active_slots()
                      if self.pos[j] < self.pref_target[j]),
                     key=lambda j: self.slots[j].submit_seq)]
        if not items:
            return 0
        if self.faults is not None and self.faults.fires("prefill_launch"):
            # the launch died before any KV write (SimulatedDeviceError
            # semantics) — every scheduled chunk simply retries next step;
            # the charge is bounded so a permanently failing launch turns
            # the oldest victim terminal instead of spinning
            self._charge_retry(items[0][0], "prefill launch faulted")
            return 0
        worked = 0
        for bkt in self.sched.plan_chunks(items):
            n, blen = len(bkt.slots), bkt.pad_len
            starts = np.asarray(bkt.starts, np.int32)
            lens = np.asarray(bkt.lens, np.int32)
            toks = np.zeros((n, blen), np.int32)
            for r, slot in enumerate(bkt.slots):
                req = self.slots[slot]
                toks[r, : lens[r]] = req.prompt[starts[r]: starts[r] + lens[r]]
            table = jnp.asarray(self.pager.table()[bkt.slots])
            if self.has_fixed:
                logits, self.pools, self.fixed = self._prefill_chunk(
                    self.params, jnp.asarray(toks), jnp.asarray(lens - 1),
                    jnp.asarray(starts), jnp.asarray(lens), table,
                    self.pools, self.fixed,
                    jnp.asarray(bkt.slots, jnp.int32))
            elif self.has_enc:
                logits, self.pools = self._prefill_chunk(
                    self.params, jnp.asarray(toks), jnp.asarray(lens - 1),
                    jnp.asarray(starts), jnp.asarray(lens), table,
                    self.pools,
                    jnp.asarray(self.pager.table("enc")[bkt.slots]),
                    jnp.asarray(self.enc_len[list(bkt.slots)]))
            else:
                logits, self.pools = self._prefill_chunk(
                    self.params, jnp.asarray(toks), jnp.asarray(lens - 1),
                    jnp.asarray(starts), jnp.asarray(lens), table,
                    self.pools)
            finals = [self.slots[s] if f else None
                      for s, f in zip(bkt.slots, bkt.final)]
            if any(bkt.final):
                self.key, sk = jax.random.split(self.key)
                temps = jnp.asarray(
                    [r.temperature if r else 0.0 for r in finals], jnp.float32)
                firsts = np.asarray(
                    self._sample_reqs(logits, sk, temps, finals))
                now = self._clock()
            for r, slot in enumerate(bkt.slots):
                self.pos[slot] += int(lens[r])
                self.stats.prefilled_tokens += int(lens[r])
                worked += 1
                if self._obs:
                    self.trace.note_chunk(slot, self.slots[slot].uid,
                                          int(lens[r]))
                if bkt.final[r]:
                    req = self.slots[slot]
                    if req._replay_tok is not None:
                        # swap-corruption re-prefill just replayed already-
                        # generated tokens: the "first token" of this prefill
                        # was sampled long ago — restore the decode feed
                        # instead of appending a duplicate
                        self.last_tok[slot] = req._replay_tok
                        req._replay_tok = None
                    else:
                        first = int(firsts[r])
                        req.output.append(first)
                        req.first_token_t = now
                        self.last_tok[slot] = first
                        if self._obs:
                            tl = self.trace.timeline(req.uid)
                            tl.add(now, "first_token", slot=slot)
                            tl.first_token_t = now
                            tl.last_emit_t = now
                            self._h_ttft.observe(now - req.arrival_t)
                    if self.cache is not None:
                        self._cache_insert_slot(slot)
            self.stats.prefill_batches += 1
        return worked

    def _sync_cache_stats(self) -> None:
        """Mirror the prefix cache's eviction counter into the engine stats.
        Must run on *every* step exit — evictions happen during admission
        (page alloc under pressure), so syncing only after a decode leaves
        ``stats.pages_evicted`` stale on steps that admit + chunk-prefill but
        have nothing to decode yet."""
        if self.cache is not None:
            self.stats.pages_evicted = self.cache.stats.evicted_pages

    # -------------------------------------------------------------- step ---
    def step(self) -> int:
        """One mixed engine step: expire deadlines, admit waiting requests,
        grow/preempt page tables as needed, advance prefilling slots by one
        budgeted chunk round, decode one token for every slot past its
        prefill target.  Returns the number of rows worked (decode slots +
        chunk rows)."""
        self._step_idx += 1
        self._retry_pending = False
        if self._obs:
            self.trace.begin_step(self._step_idx)
            pc0 = dict(self.pager.counts)
        pre_injected = 0
        if self.faults is not None:
            self.faults.begin_step(self._step_idx)
            pre_injected = self.faults.total_injected
        self._expire_deadlines()
        worked = self._step_inner()
        self._sync_cache_stats()
        if self.faults is not None:
            self.stats.faults_injected = self.faults.total_injected
            # any fire this step (e.g. a page_alloc outage rejecting an
            # otherwise-fine admission) or an active pressure window means a
            # zero-work step is fault-induced back-off, not a livelock — the
            # drain guard must keep stepping instead of raising a stall
            if (self.faults.total_injected > pre_injected
                    or self.faults.pressure_active()):
                self._retry_pending = True
        self._drain_swap_buffers()
        if self._obs:
            pc1 = self.pager.counts
            used = (self.pager.num_pages - 1) - self.pager.free_pages
            self.metrics.gauge("pool_used_pages").set(used)
            self.metrics.gauge("active_slots").set(
                sum(s is not None for s in self.slots))
            self.trace.end_step(
                self._last_dec,
                pages_used=used, pages_free=self.pager.free_pages,
                pages_grown=pc1["grown"] - pc0["grown"],
                pages_cow=pc1["cow"] - pc0["cow"],
                pages_evicted=pc1["evicted"] - pc0["evicted"])
        return worked

    def _step_inner(self) -> int:
        self._last_dec = []
        self._admit()
        stalled = self._ensure_pages()
        chunked = self._prefill_chunks()
        # decode set AFTER chunking: a slot whose final chunk just sampled
        # its first token decodes this same step (parity with the old
        # admit-then-decode flow).  Slots whose lazy growth hit an injected
        # fault sit the launch out — their tables don't cover the write
        # position yet — and retry next step on their bounded budget.
        dec = [i for i in self._active_slots()
               if i not in stalled and self.pos[i] >= self.pref_target[i]]
        if not dec:
            return chunked
        # pager tripwires: no active slot may point at the trash page, every
        # refcount must match the tables + swap holds, and the page under
        # each write cursor must be private (shared pages are read-only)
        try:
            KV.assert_live_tables(
                self.pager.table(), self.pos, self.PS,
                [s is not None and i not in stalled
                 for i, s in enumerate(self.slots)],
                refs=self.pager.refs(), held=self.pager.held(),
                cached=self.pager.cached_mask(),
                aux_tables=tuple(self.pager.table(g)
                                 for g in self.pager.groups if g != "kv"))
        except KV.PagerInvariantError as e:
            if self.strict or e.slot is None:
                raise
            # quarantine: fail the offending request, free what it held,
            # keep serving everyone else.  Skip this launch (tables may be
            # mid-repair); the next step re-checks from scratch.
            self._evict_slot(int(e.slot), "failed",
                             f"pager invariant violated: {e}")
            self._retry_pending = True
            return chunked
        if self.faults is not None and self.faults.fires("decode_launch"):
            # the launch died before dispatch — no KV write, no sample, no
            # cursor moved — so retrying next step is always sound; the
            # oldest decode slot carries the bounded charge
            self._charge_retry(min(dec, key=lambda j: self.slots[j].submit_seq),
                               "decode launch faulted")
            return chunked
        # mask mid-prefill rows out of the decode launch exactly like empty
        # slots: trash-page table rows absorb the dummy KV write and the row's
        # logits are discarded — so their real pages never see a stray write
        dset = set(dec)
        tbl_np = self.pager.table().copy()
        pos_np = self.pos.copy()
        tok_np = self.last_tok.copy()
        for i in range(self.B):
            if i not in dset:
                tbl_np[i] = KV.TRASH_PAGE
                pos_np[i] = 0
                tok_np[i] = 0
        tok = jnp.asarray(tok_np[:, None])
        pos = jnp.asarray(pos_np)
        table = jnp.asarray(tbl_np)
        if self.has_fixed:
            # trash-masking covers the KV write but not the recurrence: an
            # explicit active mask freezes non-decoding rows' fixed state
            act = np.zeros(self.B, bool)
            act[list(dset)] = True
            logits, self.pools, self.fixed = self._decode(
                self.params, self.pools, self.fixed, tok, pos, table,
                jnp.asarray(act))
        elif self.has_enc:
            # non-decoding rows read the trash page's zero rows with a
            # zero valid length (clamped to one masked row inside the
            # model) — their logits are discarded like empty slots'
            etbl = self.pager.table("enc").copy()
            elen = self.enc_len.copy()
            for i in range(self.B):
                if i not in dset:
                    etbl[i] = KV.TRASH_PAGE
                    elen[i] = 0
            logits, self.pools = self._decode(
                self.params, self.pools, tok, pos, table,
                jnp.asarray(etbl), jnp.asarray(elen))
        else:
            logits, self.pools = self._decode(
                self.params, self.pools, tok, pos, table)
        self.key, sk = jax.random.split(self.key)
        rows = [self.slots[i] if i in dset else None for i in range(self.B)]
        temps = jnp.asarray([
            r.temperature if r else 0.0 for r in rows
        ], jnp.float32)
        nxt = np.asarray(self._sample_reqs(logits, sk, temps, rows))
        self.stats.steps += 1
        self.stats.max_active = max(self.stats.max_active, len(dec))
        self.stats.active_slot_steps += len(dec)
        self._last_dec = dec
        # one clock reading covers every token this step emitted (they left
        # the same launch) — the ITL anchor and done_t share it
        now = self._clock() if self._obs else None
        for i in dec:
            req = self.slots[i]
            t = int(nxt[i])
            req.output.append(t)
            self.pos[i] += 1
            self.last_tok[i] = t
            self.stats.decoded_tokens += 1
            if self._obs:
                tl = self.trace.timeline(req.uid)
                if tl.last_emit_t is not None:
                    self._h_itl.observe(now - tl.last_emit_t)
                tl.last_emit_t = now
            hit_len = len(req.output) >= req.max_tokens
            hit_eos = t == self.eos
            # pos is the *next* write position; all S cache rows (0..S-1) are
            # writable, so the cap trips only at pos == S.  (`>= S - 1` here
            # was an off-by-one that left the last pool row of a max-length
            # request unwritten and cost it one token of budget.)
            hit_cap = self.pos[i] >= self.S
            if hit_len or hit_eos or hit_cap:
                req.done_t = now if now is not None else self._clock()
                req.finish_reason = "completed" if hit_eos else "length"
                self.stats.completed += 1
                if self._obs:
                    self._note_finish(req)
                if self.cache is not None:
                    # index the generated full pages too before the refs
                    # drop: identical continuations (multi-turn) now match
                    self._cache_insert_slot(i)
                self.slots[i] = None   # slot freed → continuous batching
                self.pos[i] = 0
                self.last_tok[i] = 0
                self.pref_target[i] = 0
                self.enc_len[i] = 0
                self.pager.free_slot(i)
        return len(dec) + chunked

    def _drain_swap_buffers(self) -> None:
        """Finish pending swap-out transfers: the async device→host copy
        started at preemption has had this whole decode step to complete, so
        materialize the rows to numpy now and drop the device-side gather
        buffer — otherwise a long-preempted request would keep its entire
        private-page image alive in device memory, which is exactly what
        swap-out exists to release.

        Fault sites: ``swap_drain`` leaves an image "in flight" another step
        (resume then device_gets it directly — correct, just not yet freed);
        ``swap_corrupt`` flips a byte of a drained image *after* its CRC-32
        was recorded, modelling host-buffer rot — the mismatch is caught at
        swap-in (:meth:`_verify_swap_image`) and the victim re-prefills.
        ``fixed_drain`` is the fixed-rows twin of ``swap_drain``: it only
        targets images carrying SSM state rows, so hybrid-specific
        resume-before-drain runs don't perturb the attention-only chaos
        suites' probe sequences."""
        for st in self._swapped.values():
            has_img = st.rows is not None or st.fixed_rows is not None
            if has_img and not st.on_host:
                site = "fixed_drain" if st.fixed_rows is not None \
                    else "swap_drain"
                if self.faults is not None and self.faults.fires(site):
                    continue                    # transfer "still in flight"
                if st.rows is not None:
                    st.rows = jax.device_get(st.rows)
                if st.fixed_rows is not None:
                    st.fixed_rows = jax.device_get(st.fixed_rows)
                st.on_host = True
                st.checksum = api.swap_image_checksum(
                    {"kv": st.rows, "fixed": st.fixed_rows})
            if (st.on_host and has_img and not st.corrupted
                    and self.faults is not None
                    and self.faults.fires("swap_corrupt")):
                img = corrupt_host_image(
                    {"kv": st.rows, "fixed": st.fixed_rows})
                st.rows, st.fixed_rows = img["kv"], img["fixed"]
                st.corrupted = True

    def _deadline_left_s(self, r: Request, now: float) -> Optional[float]:
        """Tightest remaining deadline of ``r`` in seconds: negative means
        already past due (the expiry sweep will catch it next step); ``None``
        when the request carries no deadline at all."""
        rem = []
        age = now - r.arrival_t
        if r.deadline_s is not None:
            rem.append(r.deadline_s - age)
        if r.ttft_deadline_s is not None and r.first_token_t is None:
            rem.append(r.ttft_deadline_s - age)
        return min(rem) if rem else None

    def metrics_snapshot(self) -> dict:
        """The one structured view of engine state — latency histograms
        (TTFT / ITL / e2e / queue wait / swap stall, with p50/p90/p99 under
        the documented percentile rule), cumulative :class:`EngineStats`,
        labeled counters/gauges, scheduler and pager counters, pager
        occupancy, and the live pending set (uid, phase, progress, remaining
        deadline).  ``launch/serve.py`` stat lines, the stall/max_steps
        diagnostics (via :func:`repro.serving.metrics.format_pending`), and
        ``benchmarks/run.py`` all read from here; nothing formats engine
        internals on its own anymore."""
        now = self._clock()
        pending = []
        for r in self.queue:
            pending.append({
                "uid": r.uid,
                "phase": ("swapped" if r.submit_seq in self._swapped
                          else "queued"),
                "slot": None, "pos": None, "prompt": len(r.prompt),
                "out": len(r.output), "max_tokens": r.max_tokens,
                "retries": r.retries,
                "deadline_left_s": self._deadline_left_s(r, now)})
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            pending.append({
                "uid": r.uid,
                "phase": ("prefilling" if self.pos[i] < self.pref_target[i]
                          else "decoding"),
                "slot": i, "pos": int(self.pos[i]), "prompt": len(r.prompt),
                "out": len(r.output), "max_tokens": r.max_tokens,
                "retries": r.retries,
                "deadline_left_s": self._deadline_left_s(r, now)})
        m = self.metrics.snapshot()
        return {
            "step": self._step_idx,
            "engine": dataclasses.asdict(self.stats),
            "latency": {
                name: self.metrics.histogram(name).summary()
                for name in ("ttft_s", "itl_s", "e2e_s", "queue_wait_s",
                             "swap_stall_s")},
            "counters": m["counters"],
            "gauges": m["gauges"],
            "scheduler": dict(self.sched.counts),
            "pager": {
                "free_pages": self.pager.free_pages,
                "total_pages": self.pager.num_pages - 1,
                "held": int(self.pager.held().sum()),
                "evictable": self.pager.evictable_pages(),
                "swapped_images": len(self._swapped),
                "counts": dict(self.pager.counts),
            },
            "pending": pending,
        }

    def _pending_report(self) -> str:
        """Stall/max_steps diagnostic text — a rendering of
        :meth:`metrics_snapshot`, not a second formatting path."""
        return format_pending(self.metrics_snapshot())

    def run_until_drained(self, max_steps: int = 10_000) -> EngineStats:
        """Step until queue and slots are empty.  ``max_steps`` bounds *all*
        iterations, idle ones included.  An iteration that works nothing
        while requests still wait means admission is stalled — the drain is
        single-threaded and deterministic, so no later iteration could do
        better — *unless* an injected/transient fault ate the step's work
        (``_retry_pending``), where the bounded retry budgets guarantee
        progress or a terminal ``failed``.  A genuine stall raises
        immediately under ``strict`` (naming every pending request), and
        under ``strict=False`` quarantines the blocked head
        (``finish_reason="failed"``) and keeps draining everyone else.
        Hitting the ceiling with work still pending always raises: a silent
        return here used to hand back truncated outputs that looked
        complete."""
        iters = 0
        while (self.queue or any(s is not None for s in self.slots)):
            if iters >= max_steps:
                raise RuntimeError(
                    f"run_until_drained hit max_steps={max_steps} with work "
                    f"left: {len(self.queue)} queued, "
                    f"{sum(s is not None for s in self.slots)} active "
                    f"slot(s) — raise max_steps or shrink the workload; "
                    f"pending:\n{self._pending_report()}")
            iters += 1
            if self.step() == 0 and self.queue:
                self.stats.idle_steps += 1
                if self._retry_pending:
                    continue    # fault ate this step; budgets bound the spin
                head = self.queue[0]
                swapped = head.submit_seq in self._swapped
                need = (len(self._swapped[head.submit_seq].private_lis)
                        if swapped
                        else self.sched.pages_needed(head, self.pager,
                                                     self.cache))
                free_slots = sum(s is None for s in self.slots)
                msg = (
                    f"admission stalled: queue head request uid={head.uid} "
                    f"(prompt {len(head.prompt)} tokens, "
                    f"{'swapped-out, ' if swapped else ''}"
                    f"needs {need} pages) cannot be admitted with "
                    f"free_pages={self.pager.free_pages}/"
                    f"{self.pager.num_pages - 1} "
                    f"(+{self.pager.evictable_pages()} evictable), "
                    f"free_slots={free_slots}/"
                    f"{self.B}, and no active slot can unblock it; "
                    f"pending:\n{self._pending_report()}")
                if not self.strict:
                    # degrade: the head alone is unservable — fail it, keep
                    # the engine alive for everything behind it
                    self.queue.popleft()
                    self._finish_abnormal(head, "failed", msg)
                    continue
                raise RuntimeError(msg)
        return self.stats


def load_or_quantize(
    params_fp,
    cfg: ModelConfig,
    calibration_batches,
    qcfg: QuantConfig = QuantConfig(),
    *,
    artifact_dir=None,
    refresh: bool = False,
):
    """Load-*or*-quantize engine boot (quantize once, serve many).

    If ``artifact_dir`` holds a PTQ artifact whose config hash matches
    ``(cfg, qcfg)``, the quantized pytree + report deserialize straight from
    disk — zero calibration batches consumed, zero α-search steps.  Otherwise
    (no artifact, or a stale one from a changed config) the full SmoothQuant+
    recipe runs on ``params_fp`` and, when ``artifact_dir`` is given, the
    result is persisted for the next boot.  The hash covers the *configs*,
    not the weight values — after swapping checkpoints under an unchanged
    config, pass ``refresh=True`` (CLI: ``--ptq-refresh``) to force
    re-quantization."""
    from repro.core import apply as AP

    import zipfile

    if artifact_dir is not None and not refresh and AP.has_ptq(artifact_dir):
        try:
            return AP.load_ptq(artifact_dir, cfg, qcfg)
        except (ValueError, KeyError, OSError, zipfile.BadZipFile):
            # stale config hash, unknown format version, or a corrupt /
            # truncated meta.json / arrays.npz: every recoverable-by-
            # requantizing failure falls through to the full recipe (and
            # re-saves below) — unless there are no fp params to requantize
            # from (artifact-only warm boot), where hiding the load error
            # would just crash later inside calibration
            if params_fp is None:
                raise
    qp, rep = AP.smoothquant_plus(params_fp, cfg, calibration_batches, qcfg)
    if artifact_dir is not None:
        AP.save_ptq(artifact_dir, qp, rep, cfg, qcfg)
    return qp, rep


def load_and_quantize(
    params_fp, cfg: ModelConfig, calibration_batches, qcfg: QuantConfig = QuantConfig()
):
    """Quantize-on-load (paper §2.3): FP params in, W4A16 params out.
    Kept as the artifact-free entry; see :func:`load_or_quantize`."""
    return load_or_quantize(params_fp, cfg, calibration_batches, qcfg)
