"""Continuous-batching serving engine (the vLLM role, JAX-native).

Implements the paper's deployment story: an FP16/bf16 checkpoint is handed
in, SmoothQuant+ PTQ runs once (quantize-on-load), and requests are served
from a fixed-slot continuous batcher:

- ``batch_size`` slots, each backed by a row of the decode cache;
- arriving requests are prefilled one slot at a time (their prompt KV is
  written into the slot's rows) and join the in-flight decode batch;
- every engine step decodes ONE token for all active slots (W4A16 matmuls);
- finished slots (eos / max_tokens) free immediately and are refilled from
  the queue — no head-of-line blocking, the continuous-batching win.

Slot-wise prefill keeps the engine simple (one compiled decode step + one
compiled single-slot prefill); chunked joint prefill is a perf extension.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.models import api
from repro.serving.sampling import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [T] int32
    max_tokens: int = 16
    temperature: float = 0.0
    arrival_t: float = 0.0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None


@dataclasses.dataclass
class EngineStats:
    decoded_tokens: int = 0
    prefilled_tokens: int = 0
    steps: int = 0
    completed: int = 0


class ServingEngine:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        batch_size: int = 8,
        max_seq: int = 256,
        eos_id: int = 1,
        backend: str = "auto",
        seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.S = max_seq
        self.eos = eos_id
        self.backend = backend
        self.key = jax.random.PRNGKey(seed)

        self.cache = api.init_decode_cache(cfg, batch_size, max_seq)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.pos = np.zeros(batch_size, np.int32)      # next position per slot
        self.last_tok = np.zeros(batch_size, np.int32)
        self.queue: deque[Request] = deque()
        self.stats = EngineStats()

        self._decode = jax.jit(
            lambda p, c, tok, pos: api.decode_fn(
                p, {"token": tok, "position": pos}, c, cfg, backend=backend
            )
        )
        # single-slot prefill (B=1), merged into the big cache afterwards
        self._prefill = jax.jit(
            lambda p, toks: api.prefill_fn(
                p, {"tokens": toks}, cfg, max_seq, backend=backend
            )
        )

    # ------------------------------------------------------------- admin ---
    def submit(self, req: Request):
        req.arrival_t = req.arrival_t or time.perf_counter()
        self.queue.append(req)

    def _merge_slot_cache(self, slot: int, one_cache):
        """Copy a freshly prefilled B=1 cache into row ``slot``."""
        def merge(big, one):
            if big.ndim == one.ndim and big.shape[-one.ndim:] == one.shape[-one.ndim:]:
                pass
            # batch dim position: find the axis where big == B and one == 1
            return big.at[..., slot:slot + 1, :, :, :][...].set(one) \
                if False else big

        # do it explicitly per leaf kind (batch axis position is rank-defined)
        flat_big = jax.tree_util.tree_flatten_with_path(self.cache)[0]
        flat_one = {tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path): leaf
                    for path, leaf in
                    jax.tree_util.tree_flatten_with_path(one_cache)[0]}
        new_leaves = {}
        for path, big in flat_big:
            key = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
            one = flat_one[key]
            # batch axis = first axis where big is B and one is 1
            ax = next(
                i for i, (bd, od) in enumerate(zip(big.shape, one.shape))
                if bd == self.B and od == 1
            )
            idx = [slice(None)] * big.ndim
            idx[ax] = slice(slot, slot + 1)
            new_leaves[key] = big.at[tuple(idx)].set(one.astype(big.dtype))

        def rebuild(path_tree):
            # reconstruct tree with same structure
            leaves, treedef = jax.tree_util.tree_flatten(self.cache)
            ordered = [new_leaves[tuple(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in path
            )] for path, _ in flat_big]
            return jax.tree_util.tree_unflatten(treedef, ordered)

        self.cache = rebuild(None)

    def _admit(self):
        for slot in range(self.B):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, one_cache = self._prefill(self.params, toks)
            self._merge_slot_cache(slot, one_cache)
            self.key, sk = jax.random.split(self.key)
            first = int(sample(logits, sk, temperature=req.temperature)[0])
            req.output.append(first)
            req.first_token_t = time.perf_counter()
            self.slots[slot] = req
            self.pos[slot] = len(req.prompt)
            self.last_tok[slot] = first
            self.stats.prefilled_tokens += len(req.prompt)

    # -------------------------------------------------------------- step ---
    def step(self) -> int:
        """Admit waiting requests, decode one token for all active slots.
        Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        tok = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, self.cache, tok, pos)
        self.key, sk = jax.random.split(self.key)
        temps = np.array([
            self.slots[i].temperature if self.slots[i] else 0.0
            for i in range(self.B)
        ])
        nxt = np.asarray(sample(logits, sk, temperature=float(temps.max())))
        greedy = np.asarray(jnp.argmax(logits, -1))
        nxt = np.where(temps > 0, nxt, greedy).astype(np.int32)
        self.stats.steps += 1
        for i in active:
            req = self.slots[i]
            t = int(nxt[i])
            req.output.append(t)
            self.pos[i] += 1
            self.last_tok[i] = t
            self.stats.decoded_tokens += 1
            hit_len = len(req.output) >= req.max_tokens
            hit_eos = t == self.eos
            hit_cap = self.pos[i] >= self.S - 1
            if hit_len or hit_eos or hit_cap:
                req.done_t = time.perf_counter()
                self.stats.completed += 1
                self.slots[i] = None   # slot freed → continuous batching
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> EngineStats:
        while (self.queue or any(s is not None for s in self.slots)):
            if self.stats.steps >= max_steps:
                break
            self.step()
        return self.stats


def load_and_quantize(
    params_fp, cfg: ModelConfig, calibration_batches, qcfg: QuantConfig = QuantConfig()
):
    """Quantize-on-load (paper §2.3): FP params in, W4A16 params out."""
    from repro.core.apply import smoothquant_plus

    return smoothquant_plus(params_fp, cfg, calibration_batches, qcfg)
