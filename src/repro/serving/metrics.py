"""Zero-dependency metrics core for the serving engine.

Three instrument kinds — :class:`Counter`, :class:`Gauge`,
:class:`Histogram` — each supporting label sets (``inc(site="page_alloc")``)
with a stable, sorted series keying so snapshots are deterministic and
diffable.  Histograms use **fixed-memory log-spaced buckets**: the bucket
bounds are decided at construction (``lo * 10^(i/per_decade)``), every
observation is one integer increment, and percentiles are extracted by an
exact, documented rule (below) — no sample retention, no reservoir, O(1)
memory per label set no matter how many observations land.

A :class:`MetricsRegistry` owns the instruments and an **injectable
monotonic clock** (default ``time.perf_counter``): the engine routes every
timestamp through ``registry.now()``, so tests swap in a fake clock and get
bit-stable latency histograms, timelines, and trace exports.

Percentile rule (deterministic, documented so tests can hand-compute):
for quantile ``q`` over ``count`` observations, take
``rank = ceil(q * count)`` clamped to ``[1, count]``, walk the cumulative
bucket counts to the first bucket whose cumulative count reaches ``rank``,
and report that bucket's **upper bound**, clamped into the observed
``[min, max]``.  Consequences worth knowing:

- a histogram holding one distinct value reports that exact value at every
  quantile (the clamp to ``[min, max]`` collapses the bucket bound);
- the reported quantile is never below an observation that should be under
  it (upper bound ⇒ conservative), and the relative error is bounded by the
  bucket ratio ``10^(1/per_decade)`` (~21% per bucket at the default 12
  buckets/decade — tighten ``per_decade`` to trade memory for resolution);
- overflow observations (``> bounds[-1]``) report the observed max.

:class:`HistSnap` (from ``Histogram.counts()``) supports subtraction, so a
benchmark can diff two snapshots and compute percentiles **of just the
observations in between** — this is how ``benchmarks/run.py`` derives
per-wave TTFT/ITL from a warm engine without resetting it.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "HistSnap", "MetricsRegistry",
    "percentile_from_counts", "format_pending",
]

#: canonical label-set key: sorted (k, v) pairs, values stringified
LabelKey = Tuple[Tuple[str, str], ...]


def _key(labels: dict) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_key(key: LabelKey) -> str:
    """``""`` for the unlabeled series, else ``"k1=v1,k2=v2"`` (sorted)."""
    return ",".join(f"{k}={v}" for k, v in key)


class Counter:
    """Monotonic labeled counter.  ``inc(n, **labels)``; never decreases."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, float] = {}

    def inc(self, n: float = 1, **labels) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) < 0")
        k = _key(labels)
        self._series[k] = self._series.get(k, 0) + n

    def value(self, **labels) -> float:
        return self._series.get(_key(labels), 0)

    def total(self) -> float:
        return sum(self._series.values())

    def snapshot(self) -> Dict[str, float]:
        return {_fmt_key(k): v for k, v in sorted(self._series.items())}


class Gauge:
    """Labeled point-in-time value.  ``set(v, **labels)``."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, float] = {}

    def set(self, v: float, **labels) -> None:
        self._series[_key(labels)] = v

    def value(self, **labels) -> float:
        return self._series.get(_key(labels), 0)

    def snapshot(self) -> Dict[str, float]:
        return {_fmt_key(k): v for k, v in sorted(self._series.items())}


@dataclasses.dataclass(frozen=True)
class HistSnap:
    """Immutable copy of one histogram series' bucket state.  Subtraction
    yields the observations recorded *between* the two snapshots (bucket
    counts, count and sum diff exactly; min/max are not invertible, so a
    delta carries ``None`` there and percentiles fall back to raw bucket
    bounds — fine for the benchmark use, where the bucket-ratio error bound
    still holds)."""
    bounds: Tuple[float, ...]
    buckets: Tuple[int, ...]        # len(bounds) + 1 (last = overflow)
    count: int
    sum: float
    vmin: Optional[float]
    vmax: Optional[float]

    def __sub__(self, other: "HistSnap") -> "HistSnap":
        if self.bounds != other.bounds:
            raise ValueError("histogram snapshots with different bounds")
        return HistSnap(
            bounds=self.bounds,
            buckets=tuple(a - b for a, b in
                          zip(self.buckets, other.buckets)),
            count=self.count - other.count,
            sum=self.sum - other.sum,
            vmin=None, vmax=None)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        return percentile_from_counts(
            self.bounds, self.buckets, q, vmin=self.vmin, vmax=self.vmax)


def percentile_from_counts(bounds, buckets, q, *, vmin=None, vmax=None):
    """The documented percentile rule over raw bucket counts."""
    count = sum(buckets)
    if count <= 0:
        return 0.0
    rank = min(max(math.ceil(q * count), 1), count)
    cum = 0
    val = None
    for i, c in enumerate(buckets):
        cum += c
        if cum >= rank:
            val = bounds[i] if i < len(bounds) else (
                vmax if vmax is not None else bounds[-1])
            break
    if vmin is not None:
        val = max(val, vmin)
    if vmax is not None:
        val = min(val, vmax)
    return val


class Histogram:
    """Labeled log-spaced histogram with fixed memory per series.

    Buckets: ``value <= bounds[i]`` lands in bucket ``i`` (first bucket
    catches everything ``<= lo``, including zeros/negatives); one overflow
    bucket catches ``value > bounds[-1]``.  Default range 1µs..1000s at 12
    buckets/decade = 109 bounds — sized for latencies in seconds.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", *,
                 lo: float = 1e-6, hi: float = 1e3, per_decade: int = 12):
        if not (0 < lo < hi):
            raise ValueError(f"histogram {name}: need 0 < lo < hi")
        self.name = name
        self.help = help
        n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
        self.bounds: Tuple[float, ...] = tuple(
            lo * 10 ** (i / per_decade) for i in range(n))
        self._series: Dict[LabelKey, List[int]] = {}
        self._count: Dict[LabelKey, int] = {}
        self._sum: Dict[LabelKey, float] = {}
        self._min: Dict[LabelKey, float] = {}
        self._max: Dict[LabelKey, float] = {}

    def _bucket(self, v: float) -> int:
        """Index of the first bound >= v (overflow = len(bounds)).  Binary
        search over the precomputed bounds — no float-log roundtrip, so the
        bucket edge is exact."""
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.bounds[mid] >= v:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def observe(self, v: float, **labels) -> None:
        k = _key(labels)
        b = self._series.get(k)
        if b is None:
            b = self._series[k] = [0] * (len(self.bounds) + 1)
            self._count[k] = 0
            self._sum[k] = 0.0
            self._min[k] = v
            self._max[k] = v
        b[self._bucket(v)] += 1
        self._count[k] += 1
        self._sum[k] += v
        self._min[k] = min(self._min[k], v)
        self._max[k] = max(self._max[k], v)

    def counts(self, **labels) -> HistSnap:
        k = _key(labels)
        if k not in self._series:
            return HistSnap(self.bounds, (0,) * (len(self.bounds) + 1),
                            0, 0.0, None, None)
        return HistSnap(self.bounds, tuple(self._series[k]),
                        self._count[k], self._sum[k],
                        self._min[k], self._max[k])

    def percentile(self, q: float, **labels) -> float:
        return self.counts(**labels).percentile(q)

    def summary(self, **labels) -> Dict[str, float]:
        """The stat block snapshots and report lines use: count, sum, mean, min,
        max, p50/p90/p99 — all under the documented percentile rule."""
        s = self.counts(**labels)
        return {
            "count": s.count,
            "sum": s.sum,
            "mean": s.mean,
            "min": s.vmin if s.vmin is not None else 0.0,
            "max": s.vmax if s.vmax is not None else 0.0,
            "p50": s.percentile(0.50),
            "p90": s.percentile(0.90),
            "p99": s.percentile(0.99),
        }

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {_fmt_key(k): self.summary(**dict(k))
                for k in sorted(self._series)}


class MetricsRegistry:
    """Instrument factory + snapshot root.  ``clock`` is the single time
    source for everything observability touches — the engine binds its own
    (test-swappable) ``_clock`` here, so faking the engine clock fakes every
    histogram, timeline, and trace timestamp with it."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._metrics: Dict[str, object] = {}

    def now(self) -> float:
        return self.clock()

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "", *,
                  lo: float = 1e-6, hi: float = 1e3,
                  per_decade: int = 12) -> Histogram:
        return self._get(Histogram, name, help,
                         lo=lo, hi=hi, per_decade=per_decade)

    def snapshot(self) -> Dict[str, Dict]:
        """``{"counters": {...}, "gauges": {...}, "histograms": {...}}``,
        every level sorted by name/labels — byte-stable under a fixed
        clock."""
        out: Dict[str, Dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[m.kind + "s"][name] = m.snapshot()
        return out


# --------------------------------------------------------------- report ---
def format_pending(snap: dict) -> str:
    """Render ``metrics_snapshot()["pending"]`` + pager occupancy as the
    stall/max_steps diagnostic text — the one formatting path shared by
    ``ServingEngine._pending_report`` and ``launch/serve.py``."""
    lines = []
    for p in snap["pending"]:
        d = p["deadline_left_s"]
        dtxt = f"{d:.3f}s" if d is not None else "-"
        slot = f"slot={p['slot']} pos={p['pos']} " if p["slot"] is not None \
            else ""
        lines.append(
            f"  uid={p['uid']} phase={p['phase']} "
            + (f"prompt={p['prompt']} " if p["slot"] is None else slot)
            + f"out={p['out']}/{p['max_tokens']} retries={p['retries']} "
            f"deadline={dtxt}")
    pg = snap["pager"]
    lines.append(
        f"  pager: free={pg['free_pages']}/{pg['total_pages']} "
        f"held={pg['held']} evictable={pg['evictable']} "
        f"swapped_images={pg['swapped_images']}")
    return "\n".join(lines)
