"""Per-request lifecycle timelines + per-step engine journal + Chrome export.

Two recording surfaces, both bounded-memory and host-only (no jax, no RNG —
recording must never perturb the compute graph or sampling stream):

- :class:`RequestTimeline` — timestamped lifecycle events of one request
  (``submit → admit → prefill_chunk* → first_token → preempt/swap_in →
  retry/fault → finish``), each ``(t, name, args)``.  The engine derives its
  latency histograms (TTFT, ITL, queue wait, swap stall, e2e) from these
  timestamps *as they are recorded*, so the histograms are engine-internal
  truth, not a benchmark-side stopwatch.  Finished timelines move to a
  bounded deque (oldest evicted), live ones are keyed by uid.

- :class:`StepRecord` — one journal row per engine step: decode batch size,
  chunk tokens scheduled, pages grown/COW/evicted this step, fault probes
  fired, pool occupancy.  The journal is a ring buffer (``deque(maxlen)``),
  so a million-step serve holds the last N steps only.

:func:`to_chrome_trace` renders both into Chrome ``trace_event`` JSON
(https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU)
— one track (tid) per slot plus a queue track, ``X`` complete-events for
decode/chunk work, ``C`` counter events for pool occupancy, ``i`` instants
for lifecycle marks, and ``s``/``f`` flow events stitching a request's
preempt to its resume across tracks.  Load the file in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``.  Field ordering and float
rounding are fixed so the export is byte-stable under a fake clock (golden
tested)."""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["RequestTimeline", "StepRecord", "TraceRecorder",
           "to_chrome_trace", "write_chrome_trace"]


@dataclasses.dataclass
class RequestTimeline:
    """Lifecycle events of one request + the derived-metric cursors the
    engine updates as it observes (when the request last emitted a token,
    when it was preempted, when it was admitted)."""
    uid: int
    events: List[Tuple[float, str, dict]] = dataclasses.field(
        default_factory=list)
    submit_t: Optional[float] = None
    admit_t: Optional[float] = None      # first admission only (queue wait)
    first_token_t: Optional[float] = None
    last_emit_t: Optional[float] = None  # previous token time (ITL anchor)
    preempt_t: Optional[float] = None    # open preemption (swap stall anchor)
    finish_t: Optional[float] = None

    def add(self, t: float, name: str, **args) -> None:
        self.events.append((t, name, args))


@dataclasses.dataclass
class StepRecord:
    """One engine step in the journal ring."""
    step: int
    t0: float
    t1: float
    decode_slots: Tuple[int, ...]          # slots that decoded a token
    chunks: Tuple[Tuple[int, int, int], ...]  # (slot, uid, chunk_tokens)
    preempts: Tuple[Tuple[int, int], ...]  # (uid, slot) swapped out
    resumes: Tuple[Tuple[int, int], ...]   # (uid, slot) swapped back in
    faults: Tuple[str, ...]                # fault sites fired this step
    pages_used: int
    pages_free: int
    pages_grown: int                       # lazy growth this step
    pages_cow: int                         # COW copies this step
    pages_evicted: int                     # cache evictions this step

    @property
    def chunk_tokens(self) -> int:
        return sum(c[2] for c in self.chunks)


class TraceRecorder:
    """Bounded recorder the engine writes through.  ``enabled=False`` turns
    every method into a no-op returning immediately — the metrics-off
    engine configuration used by the overhead benchmark."""

    def __init__(self, clock: Callable[[], float], *, enabled: bool = True,
                 journal_len: int = 2048, keep_finished: int = 1024):
        self.clock = clock
        self.enabled = enabled
        self.journal: deque = deque(maxlen=journal_len)
        self.live: Dict[int, RequestTimeline] = {}
        self.finished: deque = deque(maxlen=keep_finished)
        # per-step scratch, flushed by end_step()
        self._step: Optional[int] = None
        self._t0 = 0.0
        self._chunks: List[Tuple[int, int, int]] = []
        self._preempts: List[Tuple[int, int]] = []
        self._resumes: List[Tuple[int, int]] = []
        self._faults: List[str] = []

    # ------------------------------------------------------- timelines ---
    def timeline(self, uid: int) -> RequestTimeline:
        tl = self.live.get(uid)
        if tl is None:
            tl = self.live[uid] = RequestTimeline(uid)
        return tl

    def event(self, uid: int, name: str, **args) -> float:
        """Record a lifecycle event now; returns the timestamp used so the
        caller can derive a metric from the same reading."""
        t = self.clock()
        if self.enabled:
            self.timeline(uid).add(t, name, **args)
        return t

    def finish(self, uid: int) -> None:
        tl = self.live.pop(uid, None)
        if tl is not None:
            self.finished.append(tl)

    def all_timelines(self) -> List[RequestTimeline]:
        """Finished (oldest first) then live, by uid — stable export order."""
        return list(self.finished) + [
            self.live[u] for u in sorted(self.live)]

    # --------------------------------------------------------- journal ---
    def begin_step(self, step: int) -> None:
        if not self.enabled:
            return
        self._step = step
        self._t0 = self.clock()
        self._chunks = []
        self._preempts = []
        self._resumes = []
        self._faults = []

    def note_chunk(self, slot: int, uid: int, tokens: int) -> None:
        if self.enabled and self._step is not None:
            self._chunks.append((slot, uid, tokens))

    def note_preempt(self, uid: int, slot: int) -> None:
        if self.enabled and self._step is not None:
            self._preempts.append((uid, slot))

    def note_resume(self, uid: int, slot: int) -> None:
        if self.enabled and self._step is not None:
            self._resumes.append((uid, slot))

    def note_fault(self, site: str) -> None:
        if self.enabled and self._step is not None:
            self._faults.append(site)

    def end_step(self, decode_slots, *, pages_used: int, pages_free: int,
                 pages_grown: int, pages_cow: int,
                 pages_evicted: int) -> None:
        if not self.enabled or self._step is None:
            return
        self.journal.append(StepRecord(
            step=self._step, t0=self._t0, t1=self.clock(),
            decode_slots=tuple(decode_slots), chunks=tuple(self._chunks),
            preempts=tuple(self._preempts), resumes=tuple(self._resumes),
            faults=tuple(self._faults), pages_used=pages_used,
            pages_free=pages_free, pages_grown=pages_grown,
            pages_cow=pages_cow, pages_evicted=pages_evicted))
        self._step = None


# ------------------------------------------------------- chrome export ---
def _us(t: float, base: float) -> float:
    """Microseconds since base, rounded to 3 decimals (ns resolution) so the
    JSON is byte-stable across platforms' float formatting."""
    return round((t - base) * 1e6, 3)


def _ev(ph: str, name: str, ts: float, *, pid: int = 1, tid: int = 0,
        **extra) -> dict:
    """One trace event with fixed key order: name, ph, ts first — golden
    files diff cleanly."""
    d: Dict[str, Any] = {"name": name, "ph": ph, "ts": ts,
                         "pid": pid, "tid": tid}
    d.update(extra)
    return d


#: track ids: 0 = queue/lifecycle, slot s = s + 1
_QUEUE_TID = 0


def to_chrome_trace(rec: TraceRecorder, *, base: Optional[float] = None,
                    n_slots: Optional[int] = None) -> dict:
    """Render a recorder into a Chrome ``trace_event`` object.

    - metadata events name the process and one thread per slot (+ queue);
    - each journal step emits an ``X`` slice per decode slot ("decode") and
      per chunk row ("prefill_chunk", with token count), plus a ``C``
      counter sample of pool occupancy;
    - each timeline emits ``i`` instants for lifecycle marks and an
      ``s``→``f`` flow (id = uid) from every ``preempt`` to the matching
      ``swap_in``, which Perfetto draws as an arrow across slot tracks.
    """
    steps = list(rec.journal)
    tls = rec.all_timelines()
    if base is None:
        cands = [s.t0 for s in steps] + [
            tl.events[0][0] for tl in tls if tl.events]
        base = min(cands) if cands else 0.0
    if n_slots is None:
        seen = [s for st in steps for s in st.decode_slots]
        seen += [c[0] for st in steps for c in st.chunks]
        n_slots = (max(seen) + 1) if seen else 0

    events: List[dict] = [
        _ev("M", "process_name", 0, args={"name": "serving-engine"}),
        _ev("M", "thread_name", 0, tid=_QUEUE_TID,
            args={"name": "queue/lifecycle"}),
    ]
    for s in range(n_slots):
        events.append(_ev("M", "thread_name", 0, tid=s + 1,
                          args={"name": f"slot {s}"}))

    for st in steps:
        ts, dur = _us(st.t0, base), max(_us(st.t1, base) - _us(st.t0, base),
                                        0.001)
        for slot, uid, ntok in st.chunks:
            events.append(_ev("X", "prefill_chunk", ts, tid=slot + 1,
                              dur=dur,
                              args={"step": st.step, "uid": uid,
                                    "tokens": ntok}))
        for slot in st.decode_slots:
            events.append(_ev("X", "decode", ts, tid=slot + 1, dur=dur,
                              args={"step": st.step}))
        events.append(_ev("C", "pool_pages", ts,
                          args={"used": st.pages_used,
                                "free": st.pages_free}))
        for site in st.faults:
            events.append(_ev("i", f"fault:{site}", ts, s="p"))

    for tl in tls:
        for t, name, args in tl.events:
            ts = _us(t, base)
            slot = args.get("slot")
            tid = (slot + 1) if slot is not None else _QUEUE_TID
            if name == "preempt":
                # flow start: Perfetto draws preempt -> swap_in as an arrow
                events.append(_ev("i", "preempt", ts, tid=tid, s="t",
                                  args={"uid": tl.uid, **args}))
                events.append(_ev("s", "swap", ts, tid=tid, id=tl.uid,
                                  cat="swap"))
            elif name == "swap_in":
                events.append(_ev("f", "swap", ts, tid=tid, id=tl.uid,
                                  cat="swap", bp="e"))
                events.append(_ev("i", "swap_in", ts, tid=tid, s="t",
                                  args={"uid": tl.uid, **args}))
            else:
                events.append(_ev("i", name, ts, tid=tid, s="t",
                                  args={"uid": tl.uid, **args}))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, rec: TraceRecorder, *,
                       base: Optional[float] = None,
                       n_slots: Optional[int] = None) -> dict:
    """Serialize :func:`to_chrome_trace` to ``path`` (stable separators,
    sorted nothing — insertion order IS the stable order).  Returns the
    object written."""
    obj = to_chrome_trace(rec, base=base, n_slots=n_slots)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
        f.write("\n")
    return obj
