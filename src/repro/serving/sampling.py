"""Token sampling for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(
    logits: jax.Array,          # [B, V] f32
    key: jax.Array,
    *,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 1.0,
) -> jax.Array:
    """Temperature / top-k / top-p sampling.  temperature<=0 → greedy."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k and top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -1e30, logits)
    if top_p < 1.0:
        srt = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(srt, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(srt, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
