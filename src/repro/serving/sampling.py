"""Token sampling for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _filter_top_k_top_p(logits: jax.Array, top_k, top_p) -> jax.Array:
    """Mask logits outside the top-k / nucleus-p set with -inf.

    ``top_k`` / ``top_p`` may be scalars or per-row ``[B]`` arrays (mixed
    per-request settings in one batched call); ``top_k=0`` and ``top_p=1.0``
    disable the respective filter for that row.  Top-p is computed over the
    top-k-masked distribution (nucleus within the top-k set), matching the
    scalar semantics this function always had.
    """
    # statically-disabled fast path: concrete 0 / 1.0 (the defaults) compile
    # to an identity, keeping the two O(B·V·logV) sorts out of decode steps
    # whose batch uses no filtering (the engine only passes [B] arrays when
    # some active request actually sets top_k/top_p)
    if (isinstance(top_k, (int, np.integer)) and top_k == 0
            and isinstance(top_p, (int, float, np.floating)) and top_p >= 1.0):
        return logits
    b, v = logits.shape
    top_k = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (b,))
    top_p = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (b,))

    k = jnp.clip(top_k, 0, v)
    kth = jnp.take_along_axis(
        jnp.sort(logits, axis=-1)[:, ::-1],          # descending
        jnp.maximum(k, 1)[:, None] - 1, axis=-1)     # k-th largest per row
    logits = jnp.where((k[:, None] > 0) & (logits < kth), -1e30, logits)

    srt = jnp.sort(logits, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(srt, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.minimum(jnp.sum(cum < top_p[:, None], axis=-1), v - 1)
    cutoff = jnp.take_along_axis(srt, cutoff_idx[:, None], axis=-1)
    logits = jnp.where((top_p[:, None] < 1.0) & (logits < cutoff), -1e30,
                       logits)
    return logits


def sample(
    logits: jax.Array,          # [B, V] f32
    key: jax.Array,
    *,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 1.0,
) -> jax.Array:
    """Temperature / top-k / top-p sampling.  temperature<=0 → greedy."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = _filter_top_k_top_p(logits.astype(jnp.float32) / temperature,
                                 top_k, top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_per_slot(
    logits: jax.Array,          # [B, V]
    key: jax.Array,
    temperatures: jax.Array,    # [B] f32; rows with t<=0 decode greedily
    top_k=0,                    # int or [B] int32; 0 disables
    top_p=1.0,                  # float or [B] f32; 1.0 disables
) -> jax.Array:
    """Vectorized sampling with *per-row* temperature / top-k / top-p.

    One batched call serves mixed greedy/stochastic requests: row ``b`` is
    ``argmax`` when ``temperatures[b] <= 0`` and a categorical draw at its own
    temperature — filtered by its own top-k / nucleus-p — otherwise (the seed
    engine wrongly applied the batch-max temperature to every slot).
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.where(temperatures > 0, temperatures, 1.0).astype(jnp.float32)
    scaled = _filter_top_k_top_p(logits.astype(jnp.float32) / t[:, None],
                                 top_k, top_p)
    stoch = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperatures > 0, stoch, greedy)
