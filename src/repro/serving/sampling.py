"""Token sampling for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _filter_top_k_top_p(logits: jax.Array, top_k: int, top_p: float) -> jax.Array:
    """Mask logits outside the top-k / nucleus-p set with -inf."""
    if top_k and top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -1e30, logits)
    if top_p < 1.0:
        srt = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(srt, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(srt, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return logits


def sample(
    logits: jax.Array,          # [B, V] f32
    key: jax.Array,
    *,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 1.0,
) -> jax.Array:
    """Temperature / top-k / top-p sampling.  temperature<=0 → greedy."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = _filter_top_k_top_p(logits.astype(jnp.float32) / temperature,
                                 top_k, top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_per_slot(
    logits: jax.Array,          # [B, V]
    key: jax.Array,
    temperatures: jax.Array,    # [B] f32; rows with t<=0 decode greedily
    *,
    top_k: int = 0,
    top_p: float = 1.0,
) -> jax.Array:
    """Vectorized sampling with a *per-row* temperature.

    One batched call serves mixed greedy/stochastic requests: row ``b`` is
    ``argmax`` when ``temperatures[b] <= 0`` and a categorical draw at its own
    temperature otherwise (the seed engine wrongly applied the batch-max
    temperature to every slot).
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.where(temperatures > 0, temperatures, 1.0).astype(jnp.float32)
    scaled = _filter_top_k_top_p(logits.astype(jnp.float32) / t[:, None],
                                 top_k, top_p)
    stoch = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperatures > 0, stoch, greedy)
