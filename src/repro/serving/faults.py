"""Deterministic fault injection for the serving stack.

The engine's failure handling is only trustworthy if its abnormal paths run
under test as routinely as its happy path.  This module provides a seedable
:class:`FaultPlan` — a schedule of faults fired at **named injection sites**
threaded through the pager, the swap path, the prefix cache, and the engine
step — so a chaos run is *reproducible*: the same plan + seed + workload
produces the same fault sequence, and a regression is a diffable event log,
not a flake.

Injection sites (see the component that probes each):

==================  =========================================================
``page_alloc``      ``PagePool.can_alloc`` reports an allocator outage
                    (admission/growth sees "no pages" although pages exist)
``page_grow``       ``PagePool.grow`` raises :class:`TransientFault` instead
                    of allocating (engine retries with a bounded budget;
                    a mid-plan fault is rolled back by the scheduler)
``pool_pressure``   ``PagePool.can_alloc`` subtracts ``value`` phantom pages
                    for ``duration`` engine steps (a forced pressure spike —
                    exercises watermark blocking + preemption, no exception)
``swap_drain``      ``_drain_swap_buffers`` leaves the device→host copy "in
                    flight" this step (resume-before-drain path)
``swap_corrupt``    a drained host swap image has bytes flipped *after* its
                    checksum was recorded — detection happens at swap-in and
                    the victim re-prefills instead of resuming poisoned KV
``prefix_evict``    ``PrefixCache.match`` force-evicts the matched evictable
                    pages and reports a miss (the match→attach race; the
                    admission simply goes cold)
``decode_launch``   the engine's decode launch raises
                    :class:`SimulatedDeviceError` before dispatch (state
                    untouched; the step retries, budget-bounded)
``prefill_launch``  same for the chunk-prefill launch
``fixed_drain``     ``_drain_swap_buffers`` leaves a fixed-rows-bearing swap
                    image "in flight" this step (the SSM-state twin of
                    ``swap_drain`` — exercises resume-before-drain for
                    hybrid slots whose image carries state rows)
``enc_evict``       ``PrefixCache.match_exact`` force-evicts the matched
                    read-only encoder pages and reports a miss (the
                    admission re-encodes; the enc-page twin of
                    ``prefix_evict``)
==================  =========================================================

Every probe is a cheap no-op when no plan is installed (a single ``is None``
check at each site), so production paths pay nothing.

A :class:`FaultSpec` fires when **all** of its set conditions hold — typical
specs set exactly one of ``step`` (engine step index), ``op`` (the site's
N-th probe), ``every`` (periodic), or ``prob`` (seeded Bernoulli per probe) —
and at most ``times`` times (``None`` = unlimited).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

SITES = (
    "page_alloc", "page_grow", "pool_pressure", "swap_drain", "swap_corrupt",
    "prefix_evict", "decode_launch", "prefill_launch", "fixed_drain",
    "enc_evict",
)


class TransientFault(RuntimeError):
    """An injected, *retryable* failure (e.g. a page allocation that would
    have succeeded).  Handlers retry with a bounded budget; exceeding it
    turns the affected request terminal (``finish_reason="failed"``)."""


class SimulatedDeviceError(RuntimeError):
    """An injected device-launch failure (decode / prefill dispatch).  Raised
    *before* any state mutation, so a retry next step is always sound."""


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault.  Conditions AND-combine; unset ones are ignored.

    ``times`` bounds total fires (``None`` = unlimited).  ``value`` is the
    site payload (``pool_pressure``: phantom pages withheld); ``duration``
    extends a step-anchored ``pool_pressure`` spike over several steps.
    """
    site: str
    step: Optional[int] = None      # fire while engine step index matches
    op: Optional[int] = None        # fire on the site's N-th probe (0-based)
    every: Optional[int] = None     # fire on every N-th probe
    prob: float = 0.0               # seeded Bernoulli per probe
    times: Optional[int] = 1        # max fires (None = unlimited)
    value: int = 0                  # site payload (pressure pages)
    duration: int = 1               # pool_pressure: steps the spike lasts

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"known: {SITES}")
        if (self.step is None and self.op is None and self.every is None
                and not self.prob):
            raise ValueError(f"spec for {self.site!r} sets no firing "
                             "condition (step/op/every/prob)")
        if self.every is not None and self.every < 1:
            raise ValueError("every must be >= 1")


class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    Components *probe* the plan (``fires(site)``); the engine advances the
    step clock (``begin_step``).  All randomness comes from one
    ``np.random.default_rng(seed)`` consumed in probe order, and the serving
    engine is single-threaded and deterministic — so two runs of the same
    workload under the same plan inject byte-identical fault sequences.

    ``injected`` counts fires per site; ``log`` records
    ``(step, site, probe_index)`` per fire for diffable chaos reports.
    ``sink``, when set (the engine installs its observability callback),
    receives every fired site name the instant it fires — that is how each
    fault probe emits a labeled metrics counter event without this module
    importing the metrics core.
    """

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.specs = list(specs)
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._ops: Dict[str, int] = {s: 0 for s in SITES}
        self._fires_left = [s.times for s in self.specs]
        self._step = -1                  # before the first begin_step
        self.injected: Dict[str, int] = {s: 0 for s in SITES}
        self.log: List[tuple] = []
        self.pressure_hits = 0           # probes that saw an active window
        self.sink = None                 # callable(site) on fire (metrics)

    # ------------------------------------------------------------- clock ---
    def begin_step(self, step_index: int) -> None:
        """Engine hook: the current engine step index (all step-anchored
        specs key off this)."""
        self._step = step_index

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    # ------------------------------------------------------------- probes --
    def fires(self, site: str) -> bool:
        """Probe ``site``: advance its op counter and fire if any spec's
        conditions all hold (first match wins; its budget is consumed)."""
        opi = self._ops[site]
        self._ops[site] = opi + 1
        for i, spec in enumerate(self.specs):
            if spec.site != site or spec.site == "pool_pressure":
                continue
            left = self._fires_left[i]
            if left is not None and left <= 0:
                continue
            if spec.step is not None and spec.step != self._step:
                continue
            if spec.op is not None and spec.op != opi:
                continue
            if spec.every is not None and opi % spec.every != 0:
                continue
            if spec.prob and not (self._rng.random() < spec.prob):
                continue
            if left is not None:
                self._fires_left[i] = left - 1
            self.injected[site] += 1
            self.log.append((self._step, site, opi))
            if self.sink is not None:
                self.sink(site)
            return True
        return False

    def pressure_pages(self) -> int:
        """Phantom pages withheld from ``can_alloc`` this step: the summed
        ``value`` of every ``pool_pressure`` spec whose
        ``[step, step + duration)`` window covers the current step.  A
        *condition*, not an event — probing it never consumes budget or RNG
        (so it can be polled every allocation at zero determinism cost)."""
        total = 0
        for spec in self.specs:
            if spec.site != "pool_pressure" or spec.step is None:
                continue
            if spec.step <= self._step < spec.step + spec.duration:
                total += spec.value
        if total:
            self.pressure_hits += 1
        return total

    def pressure_active(self) -> bool:
        return self.pressure_pages() > 0


def corrupt_host_image(rows):
    """Return ``rows`` with one byte flipped in its first leaf — the
    ``swap_corrupt`` payload.  Deterministic (always byte 0), so a chaos
    run's corruption is reproducible; the engine's checksum must catch it
    regardless of which byte turned.  Host leaves can be read-only zero-copy
    views of device buffers, so the poisoned leaf is a writable copy and the
    (cheap, host-only) tree is rebuilt around it."""
    import jax

    leaves, treedef = jax.tree.flatten(rows)
    bad = np.array(leaves[0])            # writable host copy
    bad.reshape(-1).view(np.uint8)[0] ^= 0xFF
    return jax.tree.unflatten(treedef, [bad] + leaves[1:])
