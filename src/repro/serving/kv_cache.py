"""Paged KV cache: fixed-size pages + per-slot page tables (the vLLM idea).

The decode cache is a single device-resident *pool* per layer —
``k[num_pages, page_size, Hkv, Dh]`` — instead of one contiguous
``[B, Smax, ...]`` slab.  A host-side :class:`PagePool` hands out pages to
slots on admission and reclaims them when a request finishes, so cache memory
scales with *live tokens*, not ``batch_size × max_seq``.

Logical position ``t`` of slot ``s`` lives at
``pool[table[s, t // page_size], t % page_size]``.

Page 0 is reserved as a **trash page**: every unused page-table entry points
at it, so idle slot rows in the batched decode step scatter their garbage
writes somewhere harmless and gathers from idle slots read masked-out data.

**Sharing (shared-prefix KV cache)**: pages carry a per-page *refcount*.  The
old "owned by at most one slot" invariant relaxes to "a *full, read-only*
page may be listed in several slots' tables"; the page covering a slot's
write position is always private (refcount 1, not cache-resident).  The
prefix cache (``serving/prefix_cache.py``) registers itself as the pool's
*evictor*: pages it indexes stay resident after their last slot reference
drops (refcount 0 + cached = evictable) and are reclaimed lazily, LRU-first,
when an allocation would otherwise fail.  ``cow`` gives a slot a private
copy of a shared page before it writes into it (copy-on-write), and
``swap_out``/``swap_in`` keep shared pages resident across preemption (they
are never swapped to host with a victim — resume re-acquires them).

**Page groups (state-leaf kinds)**: the pool can serve several *groups* of
per-slot page tables over one shared free list / refcount space — ``"kv"``
(the default: read-write paged KV, everything above) plus read-only groups
like ``"enc"`` (whisper encoder K/V pages, written once at admission and
shared via the prefix-cache refcount machinery).  Every page id is owned by
at most one group at a time; read-only groups never grow during decode,
never take COWs, and survive preemption as holds
(:meth:`detach_group` / :meth:`reattach_group`) instead of host swaps.
Fixed-rows state (SSM) is *not* paged at all — it lives in per-slot device
rows owned by the engine; the pool's job there ends at the slot gate.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.faults import TransientFault

TRASH_PAGE = 0


class PagerInvariantError(RuntimeError):
    """A pager tripwire fired (stale table, refcount drift, shared-page write
    hazard).  ``slot`` names the offending slot when one is identifiable, so
    a non-strict engine can quarantine that request and keep serving; it is
    ``None`` for pool-global violations (refcount drift), which only a hard
    stop can handle safely."""

    def __init__(self, msg: str, slot: Optional[int] = None):
        super().__init__(msg)
        self.slot = slot


class PagePool:
    """Host-side page allocator over the device pools.

    Invariants (checked by :meth:`check_invariants`):
      - the trash page (page 0) is never allocated, cached, or held;
      - ``ref[p]`` equals the number of slot-table listings of ``p`` plus its
        swap holds; pages listed by several slots (or cached) are the shared
        read-only prefix pages;
      - ``free``, ``{ref > 0}``, and ``{ref == 0, cached}`` (the evictable
        set, mirrored by the evictor's LRU) partition ``{1, .., num_pages-1}``;
      - a page id is listed by at most one *group*'s tables (a kv page never
        doubles as an encoder page and vice versa).
    """

    def __init__(self, num_pages: int, page_size: int, batch_size: int,
                 max_pages_per_slot: int,
                 groups: Tuple[str, ...] = ("kv",),
                 group_max_pages: Optional[Dict[str, int]] = None):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        if page_size < 1 or max_pages_per_slot < 1:
            raise ValueError("page_size/max_pages_per_slot must be >= 1")
        if groups[0] != "kv":
            raise ValueError(f"group 'kv' must come first, got {groups!r}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.batch_size = batch_size
        self.max_pages_per_slot = max_pages_per_slot
        self.groups = tuple(groups)
        self._maxp: Dict[str, int] = {g: max_pages_per_slot for g in groups}
        if group_max_pages:
            self._maxp.update(group_max_pages)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._slot_pages_g: Dict[str, List[List[int]]] = {
            g: [[] for _ in range(batch_size)] for g in groups}
        self._table_g: Dict[str, np.ndarray] = {
            g: np.full((batch_size, self._maxp[g]), TRASH_PAGE, np.int32)
            for g in groups}
        # the "kv" group keeps its historical attribute names: every
        # read-write path (COW, swap, growth) is kv-only and indexes these
        self._slot_pages = self._slot_pages_g["kv"]
        self._table = self._table_g["kv"]
        self._ref = np.zeros(num_pages, np.int32)   # slot listings + holds
        self._held: Dict[int, int] = {}             # page -> swap-hold count
        self._cached: set = set()                   # prefix-cache resident
        self._evictor = None                        # PrefixCache (or None)
        self.faults = None                          # FaultPlan (or None)
        # cumulative page-event counters for observability: the engine's
        # step journal diffs these across a step to attribute page churn
        # (grown/COW'd/attached/freed/evicted) to the step that caused it.
        # Pure host-side ints — recording never touches device state.
        self.counts: Dict[str, int] = {
            "grown": 0, "cow": 0, "attached": 0, "freed": 0, "evicted": 0}

    # ------------------------------------------------------------- queries --
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, tokens: int) -> int:
        return max(1, -(-tokens // self.page_size))

    def evictable_pages(self) -> int:
        return self._evictor.evictable_count() if self._evictor else 0

    def can_alloc(self, n: int) -> bool:
        """Whether ``n`` pages are obtainable (free now or via LRU eviction
        of unreferenced cached pages).

        Fault sites: ``page_alloc`` reports a transient allocator outage
        (pages exist but the probe says no — admission/growth backs off and
        retries), ``pool_pressure`` withholds phantom pages for the spike's
        duration.  Both degrade through the *existing* "not enough pages"
        paths, so no caller needs fault-specific handling."""
        avail = len(self._free) + self.evictable_pages()
        if self.faults is not None:
            if self.faults.fires("page_alloc"):
                return False
            avail -= self.faults.pressure_pages()
        return n <= avail

    def slot_pages(self, slot: int, group: str = "kv") -> List[int]:
        return list(self._slot_pages_g[group][slot])

    def table(self, group: str = "kv") -> np.ndarray:
        """[B, max_pages_per_slot(group)] int32 page ids (trash-padded)."""
        return self._table_g[group]

    def page_ref(self, page: int) -> int:
        return int(self._ref[page])

    def is_cached(self, page: int) -> bool:
        return page in self._cached

    def refs(self) -> np.ndarray:
        """[num_pages] int32 refcounts (slot listings + swap holds)."""
        return self._ref

    def held(self) -> np.ndarray:
        """[num_pages] int32 swap-hold counts."""
        h = np.zeros(self.num_pages, np.int32)
        for p, n in self._held.items():
            h[p] = n
        return h

    def cached_mask(self) -> np.ndarray:
        """[num_pages] bool: page is registered (read-only) in the cache."""
        m = np.zeros(self.num_pages, bool)
        m[list(self._cached)] = True
        return m

    # --------------------------------------------------- evictor / caching --
    def set_evictor(self, evictor) -> None:
        """Register the prefix cache: it keeps unreferenced cached pages
        resident (LRU) and gives them back through :meth:`release_cached`."""
        self._evictor = evictor

    def mark_cached(self, page: int) -> None:
        """Prefix cache registered ``page`` (full, read-only from now on)."""
        if page == TRASH_PAGE:
            raise ValueError("cannot cache the trash page")
        self._cached.add(page)

    def release_cached(self, page: int) -> None:
        """Evictor reclaimed an unreferenced cached page → back to free."""
        if self._ref[page] != 0 or page not in self._cached:
            raise RuntimeError(f"page {page} is not an evictable cached page")
        self._cached.discard(page)
        self._free.append(page)
        self.counts["evicted"] += 1

    def _take_free(self, n: int) -> List[int]:
        """Pop ``n`` free pages, evicting LRU cached pages as needed."""
        while len(self._free) < n and self._evictor is not None \
                and self._evictor.evict_one():
            pass
        if n > len(self._free):
            raise RuntimeError(f"out of pages: need {n}, free {len(self._free)}")
        return [self._free.pop() for _ in range(n)]

    def _release(self, page: int) -> None:
        """Drop one reference; an unreferenced page returns to the free list
        unless the prefix cache still indexes it (→ evictable, LRU)."""
        self._ref[page] -= 1
        assert self._ref[page] >= 0, f"refcount underflow on page {page}"
        if self._ref[page] == 0:
            if page in self._cached:
                self._evictor.on_unreferenced(page)
            else:
                self._free.append(page)
                self.counts["freed"] += 1

    # ------------------------------------------------------- alloc / free ---
    def alloc(self, slot: int, n: int) -> List[int]:
        """Give ``slot`` ``n`` pages.  The slot must currently own none."""
        if self._slot_pages[slot]:
            raise RuntimeError(f"slot {slot} already owns pages")
        return self.grow(slot, n)

    def grow(self, slot: int, n: int = 1, group: str = "kv") -> List[int]:
        """Append ``n`` fresh private pages to ``slot`` (which may already
        own some).

        This is what lazy decode growth calls when a slot's write position
        crosses a page boundary: the new pages extend the slot's page-table
        prefix, so already-written logical positions keep their mapping.
        Non-``"kv"`` groups use the same path at admission time only (an
        encoder allocation is a one-shot grow, never incremental).
        """
        sp, tab = self._slot_pages_g[group], self._table_g[group]
        owned = len(sp[slot])
        if owned + n > self._maxp[group]:
            raise ValueError(
                f"slot {slot} would own {owned + n} {group} pages > "
                f"max={self._maxp[group]}")
        if self.faults is not None and self.faults.fires("page_grow"):
            # raised before any allocation, so the pool is untouched: the
            # engine retries next step (bounded budget) and a mid-plan fault
            # is rolled back by the scheduler's admission abort
            raise TransientFault(
                f"injected page_grow fault (slot {slot}, n={n})")
        pages = self._take_free(n)
        for p in pages:
            self._ref[p] = 1
        sp[slot].extend(pages)
        tab[slot, owned : owned + n] = pages
        self.counts["grown"] += n
        return pages

    def attach(self, slot: int, pages: List[int], group: str = "kv") -> None:
        """Share resident pages into ``slot``'s table (prefix-cache hit).

        The pages must be resident — referenced by another slot, held by a
        swapped-out request, or cache-resident — and are appended to the
        slot's logical page list in order.  Each gains one reference; an
        evictable page becomes pinned (leaves the evictor's LRU).
        """
        sp, tab = self._slot_pages_g[group], self._table_g[group]
        owned = len(sp[slot])
        if owned + len(pages) > self._maxp[group]:
            raise ValueError(
                f"slot {slot} would own {owned + len(pages)} {group} pages "
                f"> max={self._maxp[group]}")
        for p in pages:
            if p == TRASH_PAGE:
                raise ValueError("cannot attach the trash page")
            if self._ref[p] == 0:
                if p not in self._cached:
                    raise RuntimeError(f"page {p} is not resident (freed?)")
                self._evictor.on_referenced(p)
            self._ref[p] += 1
        sp[slot].extend(pages)
        tab[slot, owned : owned + len(pages)] = pages
        self.counts["attached"] += len(pages)

    def cow(self, slot: int, logical_idx: int, *,
            hold_src: bool = False) -> Tuple[int, int]:
        """Copy-on-write: replace ``slot``'s shared logical page with a fresh
        private one.  Returns ``(src, dst)`` pool page ids — the caller must
        copy the device rows ``src → dst`` before the slot reads or writes
        that logical page.

        With ``hold_src`` the slot's reference on ``src`` becomes a *hold*
        instead of being released, pinning the page (un-evictable, un-
        reallocatable) until the caller performs the device copy and calls
        :meth:`drop_hold`.  Without it, a released ``src`` whose refcount
        hits 0 is immediately evictable — a later allocation in the same
        planning pass could reclaim and overwrite it before a deferred copy
        reads it."""
        old = self._slot_pages[slot][logical_idx]
        new = self._take_free(1)[0]
        self._ref[new] = 1
        self.counts["cow"] += 1
        self._slot_pages[slot][logical_idx] = new
        self._table[slot, logical_idx] = new
        if hold_src:
            self._held[old] = self._held.get(old, 0) + 1
        else:
            self._release(old)
        return old, new

    def drop_hold(self, page: int) -> None:
        """Release one hold on ``page`` (COW source copied, or a swap image
        discarded): the reference it kept alive is dropped normally."""
        held = self._held[page] - 1
        if held:
            self._held[page] = held
        else:
            del self._held[page]
        self._release(page)

    def free_slot(self, slot: int) -> None:
        """Release every page ``slot`` lists, across *all* groups.  Read-only
        group pages registered in the cache simply become evictable; private
        ones return to the free list."""
        for g in self.groups:
            self.free_group(slot, g)

    def free_group(self, slot: int, group: str) -> None:
        """Release just ``slot``'s pages of one group (e.g. drop the fresh
        encoder pages an admission pre-allocated before its cache hit)."""
        sp, tab = self._slot_pages_g[group], self._table_g[group]
        for p in sp[slot]:
            self._release(p)
        sp[slot] = []
        tab[slot, :] = TRASH_PAGE

    def detach_group(self, slot: int, group: str) -> List[int]:
        """Preempt a read-only group: the slot's references on its pages
        become *swap holds* (pinned — not evictable, not reallocatable) and
        the table row clears.  The page data never leaves the device (the
        group is read-only), so there is nothing to host-swap; resume calls
        :meth:`reattach_group` with the returned page list."""
        sp, tab = self._slot_pages_g[group], self._table_g[group]
        pages = sp[slot]
        for p in pages:
            self._held[p] = self._held.get(p, 0) + 1
        sp[slot] = []
        tab[slot, :] = TRASH_PAGE
        return pages

    def reattach_group(self, slot: int, group: str, pages: List[int]) -> None:
        """Resume a read-only group: each hold from :meth:`detach_group`
        converts back into a slot reference, in order."""
        sp, tab = self._slot_pages_g[group], self._table_g[group]
        if sp[slot]:
            raise RuntimeError(f"slot {slot} already owns {group} pages")
        for p in pages:
            held = self._held[p] - 1
            if held:
                self._held[p] = held
            else:
                del self._held[p]
        sp[slot] = list(pages)
        tab[slot, : len(pages)] = pages

    def drop_group_holds(self, pages: List[int]) -> None:
        """Abandon a detached read-only group (its request finished or was
        re-admitted from scratch): drop each hold; cached pages turn
        evictable, uncached ones free."""
        for p in pages:
            self.drop_hold(p)

    # ------------------------------------------------------- swap support ---
    def split_for_swap(self, slot: int) -> Tuple[List[Tuple[int, int]],
                                                 List[Tuple[int, int]]]:
        """Partition ``slot``'s pages into ``(kept, private)`` lists of
        ``(logical_idx, page)``.  *Kept* pages are shared (refcount > 1) or
        cache-resident: they are never swapped to host with a victim — they
        stay in the pool and resume re-acquires them.  *Private* pages are
        the ones whose rows must round-trip through the host swap buffer."""
        kept, private = [], []
        for li, p in enumerate(self._slot_pages[slot]):
            if self._ref[p] > 1 or p in self._cached:
                kept.append((li, p))
            else:
                private.append((li, p))
        return kept, private

    def swap_out(self, slot: int,
                 split: Optional[Tuple[List[Tuple[int, int]],
                                       List[Tuple[int, int]]]] = None
                 ) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int]]]:
        """Preemption: release ``slot``'s private pages (their rows must
        already be captured) and convert its references on shared/cached
        pages into *swap holds* so they cannot be evicted or freed while the
        request waits off-device.  Returns the :meth:`split_for_swap`
        partition.

        ``split`` is the caller's earlier :meth:`split_for_swap` result (the
        engine computes it first to gather the private rows): it is validated
        against the slot's current pages, so a pager mutation sneaking in
        between the gather and the swap-out fails loudly instead of freeing
        pages whose rows were never captured."""
        kept, private = split if split is not None else self.split_for_swap(slot)
        if sorted(kept + private) != list(enumerate(self._slot_pages[slot])):
            raise RuntimeError(
                f"swap_out partition is stale for slot {slot}: the pager "
                "changed between split_for_swap and swap_out")
        for _, p in kept:
            # the slot's reference becomes a hold: _ref stays, accounting moves
            self._held[p] = self._held.get(p, 0) + 1
        for _, p in private:
            self._release(p)
        self._slot_pages[slot] = []
        self._table[slot, :] = TRASH_PAGE
        return kept, private

    def swap_in(self, slot: int, kept: List[Tuple[int, int]],
                private_lis: List[int]) -> List[int]:
        """Resume a preempted request into ``slot``: re-acquire its held
        shared pages (hold → slot reference) and allocate fresh private pages
        at the given logical indices.  Returns the fresh page ids in
        ``private_lis`` order, ready for the swap-buffer scatter."""
        if self._slot_pages[slot]:
            raise RuntimeError(f"slot {slot} already owns pages")
        fresh = self._take_free(len(private_lis))
        entries: Dict[int, int] = {}
        for li, p in kept:
            held = self._held[p] - 1
            if held:
                self._held[p] = held
            else:
                del self._held[p]
            entries[li] = p
        for li, p in zip(private_lis, fresh):
            self._ref[p] = 1
            entries[li] = p
        if sorted(entries) != list(range(len(entries))):
            raise RuntimeError(f"swap-in logical pages not contiguous: "
                               f"{sorted(entries)}")
        pages = [entries[li] for li in range(len(entries))]
        self._slot_pages[slot] = pages
        self._table[slot, : len(pages)] = pages
        return fresh

    # ---------------------------------------------------------- invariants --
    def check_invariants(self) -> None:
        counts = np.zeros(self.num_pages, np.int64)
        group_of: Dict[int, str] = {}
        for g in self.groups:
            for sp in self._slot_pages_g[g]:
                for p in sp:
                    counts[p] += 1
                    other = group_of.setdefault(p, g)
                    assert other == g, (
                        f"page {p} listed by both {other!r} and {g!r} "
                        "group tables")
        held = self.held()
        assert counts[TRASH_PAGE] == 0, "trash page was allocated"
        assert TRASH_PAGE not in self._free, "trash page in free list"
        assert TRASH_PAGE not in self._cached, "trash page cached"
        assert held[TRASH_PAGE] == 0, "trash page held"
        assert (self._ref == counts + held).all(), (
            "refcounts out of sync with slot tables + swap holds")
        free = set(self._free)
        assert len(free) == len(self._free), "free list duplicate"
        referenced = set(np.nonzero(self._ref)[0].tolist())
        evictable = {p for p in self._cached if self._ref[p] == 0}
        assert not (free & referenced), "free page still referenced"
        assert not (free & self._cached), "free page still cached"
        assert free | referenced | evictable == set(
            range(1, self.num_pages)), "page leak / invention"
        if self._evictor is not None:
            assert set(self._evictor.evictable_page_ids()) == evictable, (
                "evictor LRU out of sync with unreferenced cached pages")
        else:
            assert not evictable, "cached pages with no evictor registered"
        for g in self.groups:
            tab = self._table_g[g]
            for s, sp in enumerate(self._slot_pages_g[g]):
                assert tab[s, : len(sp)].tolist() == sp, \
                    f"{g} table out of sync"
                assert (tab[s, len(sp):] == TRASH_PAGE).all(), \
                    f"{g} table out of sync (tail)"
                assert len(set(sp)) == len(sp), \
                    f"slot {s} lists a {g} page twice"


# ------------------------------------------------------- device-side ops ----
def prefix_write_plan(lens: np.ndarray, table_rows: np.ndarray,
                      page_size: int, pad_len: int,
                      starts: Optional[np.ndarray] = None):
    """Destination (page, offset) for each (row, t) of a padded prefill.

    ``lens[n]`` are true written lengths, ``table_rows[n, P]`` the page-table
    rows of the slots the tokens land in.  ``starts[n]`` (default 0) is the
    logical position of each row's *first* written token — a suffix-only
    prefill behind a cached prefix passes the per-row matched prefix length,
    so token ``t`` of row ``n`` lands at logical position ``starts[n] + t``.
    Padding positions (``t >= len``) are routed to the trash page.  Returns
    int32 ``(page[n, T], off[n, T])``.
    """
    n = len(lens)
    t_idx = np.arange(pad_len)[None, :]
    mask = t_idx < np.asarray(lens)[:, None]
    pos = t_idx if starts is None else t_idx + np.asarray(starts)[:, None]
    slot_pg = np.minimum(pos // page_size, table_rows.shape[1] - 1)
    page = np.where(mask, table_rows[np.arange(n)[:, None], slot_pg], TRASH_PAGE)
    off = np.broadcast_to(pos % page_size, (n, pad_len))
    return page.astype(np.int32), off.astype(np.int32)


@functools.partial(jax.jit, donate_argnums=(0,))
def write_prefix(pools: Any, kv: Any, page: jax.Array, off: jax.Array) -> Any:
    """Scatter raw prefix KV into the pools.

    ``pools`` leaves are ``[L, num_pages, page_size, ...]``; ``kv`` leaves are
    the matching raw prefill caches ``[L, n, T, ...]``; ``page``/``off`` are
    ``[n, T]`` from :func:`prefix_write_plan`.
    """
    def put(pool, new):
        return pool.at[:, page, off].set(new.astype(pool.dtype))

    return jax.tree.map(put, pools, kv)


def assert_live_tables(table, write_pos, page_size: int, active, *,
                       refs=None, held=None, cached=None,
                       aux_tables=()) -> None:
    """Pager tripwires, vectorized (pure numpy — this runs every engine step).

    Stale-table detection: an *active* slot's live page-table prefix must
    never reference the trash page — table[s, p] == 0 for p within the pages
    covering positions ``0..write_pos[s]`` means the slot's pages were freed
    (or never allocated) while it is still decoding, i.e. a pager
    use-after-free.

    With ``refs`` (+ optional ``held``/``cached`` from the pool), refcounts
    are validated too: every non-trash table entry must be counted by
    ``refs`` (``refs == table occurrences + swap holds``), and the page an
    active slot is about to write (logical page ``write_pos // page_size``)
    must be *private and writable* — exactly one reference, no swap hold, and
    not registered read-only in the prefix cache (shared pages take a
    copy-on-write before any write reaches them).

    ``aux_tables`` carries the pool's non-KV page-group tables (e.g. the
    read-only encoder group): their listings join the refcount census —
    every group's references share one counter — but they are exempt from
    the stale/write-cursor checks, which are about the decode write path
    and only KV pages are ever written mid-decode.

    Raises :class:`PagerInvariantError` (a ``RuntimeError``) naming the
    slot/page instead of letting the decode silently read or clobber shared
    state; slot-attributable violations carry ``.slot`` so a non-strict
    engine can quarantine the one offending request and keep serving.
    """
    table = np.asarray(table)
    write_pos = np.asarray(write_pos)
    active = np.asarray(active, bool)
    b, p_max = table.shape
    need = write_pos // page_size + 1           # pages covering 0..write_pos
    cols = np.arange(p_max)[None, :]
    live = active[:, None] & (cols < need[:, None])
    stale = live & (table == TRASH_PAGE)
    if stale.any():
        s, lp = np.argwhere(stale)[0]
        raise PagerInvariantError(
            f"stale page table: active slot {int(s)} (write position "
            f"{int(write_pos[s])}) references the freed/trash page at "
            f"logical page {int(lp)} — pages were reclaimed while "
            "the slot was still decoding", slot=int(s))
    if refs is None:
        return
    refs = np.asarray(refs)
    held = np.zeros_like(refs) if held is None else np.asarray(held)
    # every table listing is counted: refs == occurrences + swap holds
    occ = np.bincount(table[table != TRASH_PAGE].ravel(),
                      minlength=refs.shape[0])
    for aux in aux_tables:
        aux = np.asarray(aux)
        occ += np.bincount(aux[aux != TRASH_PAGE].ravel(),
                           minlength=refs.shape[0])
    bad = np.nonzero(refs != occ + held)[0]
    bad = bad[bad != TRASH_PAGE]
    if bad.size:
        p = int(bad[0])
        raise PagerInvariantError(
            f"refcount out of sync: page {p} has ref={int(refs[p])} but "
            f"{int(occ[p])} table listings + {int(held[p])} swap holds")
    # the page under each active slot's write cursor must be private
    wp_page = table[np.arange(b), np.minimum(write_pos // page_size,
                                             p_max - 1)]
    not_private = active & (refs[wp_page] - held[wp_page] != 1)
    not_private |= active & (held[wp_page] != 0)
    if cached is not None:
        not_private |= active & np.asarray(cached)[wp_page]
    if not_private.any():
        s = int(np.argmax(not_private))
        p = int(wp_page[s])
        raise PagerInvariantError(
            f"shared-page write hazard: active slot {s} would write position "
            f"{int(write_pos[s])} into page {p} (ref={int(refs[p])}, "
            f"held={int(held[p])}"
            + (f", cached={bool(np.asarray(cached)[p])}" if cached is not None
               else "")
            + ") — shared/cached pages are read-only and need copy-on-write",
            slot=s)


# canonical page gather lives next to the attention decode paths that
# consume it (the jnp reference for the Pallas paged-attention kernel);
# re-exported here so pager users/tests need only this module
from repro.models.attention import gather_pages  # noqa: E402,F401
