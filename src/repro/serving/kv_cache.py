"""Paged KV cache: fixed-size pages + per-slot page tables (the vLLM idea).

The decode cache is a single device-resident *pool* per layer —
``k[num_pages, page_size, Hkv, Dh]`` — instead of one contiguous
``[B, Smax, ...]`` slab.  A host-side :class:`PagePool` hands out pages to
slots on admission and reclaims them when a request finishes, so cache memory
scales with *live tokens*, not ``batch_size × max_seq``.

Logical position ``t`` of slot ``s`` lives at
``pool[table[s, t // page_size], t % page_size]``.

Page 0 is reserved as a **trash page**: every unused page-table entry points
at it, so idle slot rows in the batched decode step scatter their garbage
writes somewhere harmless and gathers from idle slots read masked-out data.
"""
from __future__ import annotations

import functools
from typing import Any, List

import jax
import jax.numpy as jnp
import numpy as np

TRASH_PAGE = 0


class PagePool:
    """Host-side page allocator over the device pools.

    Invariants (checked by :meth:`check_invariants`):
      - the trash page (page 0) is never allocated;
      - a page is owned by at most one slot;
      - ``free ∪ allocated == {1, .., num_pages-1}`` at all times.
    """

    def __init__(self, num_pages: int, page_size: int, batch_size: int,
                 max_pages_per_slot: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        if page_size < 1 or max_pages_per_slot < 1:
            raise ValueError("page_size/max_pages_per_slot must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self.batch_size = batch_size
        self.max_pages_per_slot = max_pages_per_slot
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._slot_pages: List[List[int]] = [[] for _ in range(batch_size)]
        self._table = np.full((batch_size, max_pages_per_slot), TRASH_PAGE,
                              np.int32)

    # ------------------------------------------------------------- queries --
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, tokens: int) -> int:
        return max(1, -(-tokens // self.page_size))

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._slot_pages[slot])

    def table(self) -> np.ndarray:
        """[B, max_pages_per_slot] int32 page ids (trash-padded)."""
        return self._table

    # ------------------------------------------------------- alloc / free ---
    def alloc(self, slot: int, n: int) -> List[int]:
        """Give ``slot`` ``n`` pages.  The slot must currently own none."""
        if self._slot_pages[slot]:
            raise RuntimeError(f"slot {slot} already owns pages")
        return self.grow(slot, n)

    def grow(self, slot: int, n: int = 1) -> List[int]:
        """Append ``n`` pages to ``slot`` (which may already own some).

        This is what lazy decode growth calls when a slot's write position
        crosses a page boundary: the new pages extend the slot's page-table
        prefix, so already-written logical positions keep their mapping.
        """
        owned = len(self._slot_pages[slot])
        if owned + n > self.max_pages_per_slot:
            raise ValueError(
                f"slot {slot} would own {owned + n} pages > "
                f"max_pages_per_slot={self.max_pages_per_slot}")
        if n > len(self._free):
            raise RuntimeError(f"out of pages: need {n}, free {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._slot_pages[slot].extend(pages)
        self._table[slot, owned : owned + n] = pages
        return pages

    def free_slot(self, slot: int) -> None:
        self._free.extend(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._table[slot, :] = TRASH_PAGE

    def check_invariants(self) -> None:
        allocated = [p for sp in self._slot_pages for p in sp]
        assert TRASH_PAGE not in allocated, "trash page was allocated"
        assert TRASH_PAGE not in self._free, "trash page in free list"
        assert len(set(allocated)) == len(allocated), "page double-owned"
        assert sorted(allocated + self._free) == list(
            range(1, self.num_pages)), "page leak / invention"
        live = self._table[self._table != TRASH_PAGE].tolist()
        assert sorted(live) == sorted(allocated), "table out of sync"


# ------------------------------------------------------- device-side ops ----
def prefix_write_plan(lens: np.ndarray, table_rows: np.ndarray,
                      page_size: int, pad_len: int):
    """Destination (page, offset) for each (row, t) of a padded prefill.

    ``lens[n]`` are true prompt lengths, ``table_rows[n, P]`` the page-table
    rows of the slots the prompts land in.  Padding positions (``t >= len``)
    are routed to the trash page.  Returns int32 ``(page[n, T], off[n, T])``.
    """
    n = len(lens)
    t_idx = np.arange(pad_len)[None, :]
    mask = t_idx < np.asarray(lens)[:, None]
    slot_pg = np.minimum(t_idx // page_size, table_rows.shape[1] - 1)
    page = np.where(mask, table_rows[np.arange(n)[:, None], slot_pg], TRASH_PAGE)
    off = np.broadcast_to(t_idx % page_size, (n, pad_len))
    return page.astype(np.int32), off.astype(np.int32)


@functools.partial(jax.jit, donate_argnums=(0,))
def write_prefix(pools: Any, kv: Any, page: jax.Array, off: jax.Array) -> Any:
    """Scatter raw prefix KV into the pools.

    ``pools`` leaves are ``[L, num_pages, page_size, ...]``; ``kv`` leaves are
    the matching raw prefill caches ``[L, n, T, ...]``; ``page``/``off`` are
    ``[n, T]`` from :func:`prefix_write_plan`.
    """
    def put(pool, new):
        return pool.at[:, page, off].set(new.astype(pool.dtype))

    return jax.tree.map(put, pools, kv)


def assert_live_tables(table, write_pos, page_size: int, active) -> None:
    """Stale-table detection: an *active* slot's live page-table prefix must
    never reference the trash page — table[s, p] == 0 for p within the pages
    covering positions ``0..write_pos[s]`` means the slot's pages were freed
    (or never allocated) while it is still decoding, i.e. a pager
    use-after-free.  Raises ``RuntimeError`` naming the slot and logical page
    instead of letting the decode silently read/clobber the trash page.
    """
    table = np.asarray(table)
    write_pos = np.asarray(write_pos)
    need = write_pos // page_size + 1       # pages covering 0..write_pos
    for s in np.nonzero(np.asarray(active))[0]:
        row = table[s, : need[s]]
        stale = np.nonzero(row == TRASH_PAGE)[0]
        if stale.size:
            raise RuntimeError(
                f"stale page table: active slot {int(s)} (write position "
                f"{int(write_pos[s])}) references the freed/trash page at "
                f"logical page {int(stale[0])} — pages were reclaimed while "
                "the slot was still decoding")


# canonical page gather lives next to the attention decode paths that
# consume it (the jnp reference for the Pallas paged-attention kernel);
# re-exported here so pager users/tests need only this module
from repro.models.attention import gather_pages  # noqa: E402,F401
