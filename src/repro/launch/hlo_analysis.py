"""Structural HLO analysis: collective bytes with while-loop trip counts.

GSPMD inserts collectives INSIDE scan loop bodies; summing naively over the
HLO text counts them once.  This parser:

1. splits the module into computations,
2. finds ``while`` ops, their body/condition computations, and recovers the
   trip count from the condition's ``constant(N)`` bound,
3. propagates multipliers along the call graph (fusions/calls keep the
   caller's multiplier; while-bodies multiply by trip count),
4. sums per-collective result bytes × multiplier.

Result bytes are the per-device data landing in memory for that op — the
standard per-device proxy for link traffic.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COMP_RE = re.compile(r"^(?:%?([\w.\-_]+))\s*(?:\([^)]*\))?\s*->.*?\{|^ENTRY\s+%?([\w.\-_]+)", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE_RE = re.compile(
    r"while\([^)]*\)[^\n]*?condition=%?([\w.\-_]+)[^\n]*?body=%?([\w.\-_]+)"
    r"|while\([^)]*\)[^\n]*?body=%?([\w.\-_]+)[^\n]*?condition=%?([\w.\-_]+)"
)
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w.\-_]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def split_computations(hlo: str) -> Dict[str, str]:
    """computation name → body text (brace-matched)."""
    comps: Dict[str, str] = {}
    i = 0
    header = re.compile(
        r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*(?:\([^{;]*?\))?\s*->[^{\n]*\{", re.M
    )
    for m in header.finditer(hlo):
        name = m.group(1)
        # brace matching from end of header
        depth, j = 1, m.end()
        while j < len(hlo) and depth:
            if hlo[j] == "{":
                depth += 1
            elif hlo[j] == "}":
                depth -= 1
            j += 1
        comps[name] = hlo[m.end(): j]
    return comps


def _entry_name(hlo: str, comps: Dict[str, str]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-_]+)", hlo, re.M)
    if m:
        return m.group(1)
    return max(comps, key=lambda k: len(comps[k]))


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt or ""):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def trip_count(cond_body: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return max(consts) if consts else 1


def computation_multipliers(hlo: str, comps: Dict[str, str]) -> Dict[str, float]:
    entry = _entry_name(hlo, comps)
    mult: Dict[str, float] = {entry: 1.0}
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(12):
        changed = False
        for name, body in comps.items():
            m = mult.get(name)
            if m is None:
                continue
            for w in _WHILE_RE.finditer(body):
                cond = w.group(1) or w.group(4)
                wbody = w.group(2) or w.group(3)
                if cond in comps:
                    trips = trip_count(comps[cond])
                else:
                    trips = 1
                for target, factor in ((wbody, trips), (cond, trips)):
                    if target in comps:
                        new = m * factor
                        if mult.get(target, 0) < new:
                            mult[target] = new
                            changed = True
            for c in _CALL_RE.finditer(body):
                t = c.group(1)
                if t in comps and mult.get(t, 0) < m:
                    mult[t] = m
                    changed = True
        if not changed:
            break
    return mult


def collective_bytes(hlo: str) -> Dict[str, float]:
    comps = split_computations(hlo)
    mult = computation_multipliers(hlo, comps)
    out: Dict[str, float] = {}
    for name, body in comps.items():
        m = mult.get(name, 1.0)
        for line in body.splitlines():
            stripped = line.strip()
            eq = stripped.find("= ")
            if eq < 0:
                continue
            rhs = stripped[eq + 2:]
            for kind in _COLLECTIVES:
                # op name directly after the result shape; exclude -done lines
                if re.match(rf"[\w\[\],{{}}: ]*?\b{kind}(-start)?\(", rhs):
                    shp = rhs.split(kind)[0]
                    out[kind] = out.get(kind, 0.0) + _shape_bytes(shp) * m
                    break
    return out
