"""Scan-aware analytic cost model over jaxprs.

``compiled.cost_analysis()`` counts a ``while``/``scan`` body ONCE, which
under-counts an 88-layer scanned transformer by 88×.  This walker traverses
the (pre-partitioning) jaxpr, multiplying through scan trip counts, and
counts:

- FLOPs: dot_general (2·batch·M·N·K), conv, plus 1 flop/elt for major
  elementwise ops (negligible but free to count);
- HBM bytes at *materialization points*: dot operands/results, scan
  carries/stacked outputs, gathers (embeddings), dynamic-update-slice (KV
  cache writes), and rematerialized recompute (visible in the differentiated
  jaxpr) — fused elementwise chains are deliberately NOT counted, matching
  how a TPU would see them.

These are GLOBAL (all-device) numbers; divide by chip count downstream.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np

FLOP_ELEMENTWISE = {
    "add", "mul", "sub", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "integer_pow", "pow",
}


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = int(np.prod([lhs.shape[i] for i in lb])) if lb else 1
    k = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
    m = int(np.prod([d for i, d in enumerate(lhs.shape) if i not in lc and i not in lb]))
    n = int(np.prod([d for i, d in enumerate(rhs.shape) if i not in rc and i not in rb]))
    return 2 * batch * m * n * k


# ops through which the "effective stream width" propagates (they fuse into
# the consumer on TPU: a convert/mul chain from an int8 source streams 1 B/elt)
_CHAIN_PRIMS = {
    "convert_element_type", "mul", "add", "sub", "broadcast_in_dim",
    "reshape", "transpose", "squeeze", "expand_dims", "copy", "concatenate",
}
# nibble unpack: lo/hi halves share one packed-byte read → eff halves
_NIBBLE_PRIMS = {"and", "shift_right_logical", "or"}


def _base_item(aval) -> int:
    """Stream width: floats capped at bf16 (f32 in the jaxpr is a fused
    convert on TPU); ints keep their true width (packed int4 → 1 B)."""
    item = aval.dtype.itemsize
    if aval.dtype.kind == "f":
        item = min(item, 2)
    return item


def _eff_item(v, var_eff) -> int:
    if hasattr(v, "val"):          # literal
        return _base_item(v.aval) if hasattr(v, "aval") else 4
    return var_eff.get(id(v), _base_item(v.aval))


def _io_bytes(eqn, var_eff) -> int:
    total = 0
    for v in eqn.invars:
        if hasattr(v, "aval"):
            total += int(np.prod(v.aval.shape)) * _eff_item(v, var_eff)
    for v in eqn.outvars:
        total += int(np.prod(v.aval.shape)) * _base_item(v.aval)
    return total


class Cost:
    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.by_prim: Dict[str, float] = {}

    def add(self, prim: str, flops: float, byts: float, mult: float):
        self.flops += flops * mult
        self.bytes += byts * mult
        self.by_prim[prim] = self.by_prim.get(prim, 0.0) + flops * mult


def _walk(jaxpr, cost: Cost, mult: float, var_eff=None):
    var_eff = {} if var_eff is None else var_eff
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in _CHAIN_PRIMS:
            # propagate effective stream width through fusible chains
            effs = [_eff_item(v, var_eff) for v in eqn.invars if hasattr(v, "aval")
                    and v.aval.shape == eqn.outvars[0].aval.shape] or \
                   [_eff_item(v, var_eff) for v in eqn.invars if hasattr(v, "aval")]
            if effs:
                var_eff[id(eqn.outvars[0])] = min(
                    min(effs), _base_item(eqn.outvars[0].aval))
        elif prim in _NIBBLE_PRIMS:
            ins = [_eff_item(v, var_eff) for v in eqn.invars if hasattr(v, "aval")]
            if ins and eqn.invars[0].aval.dtype.itemsize == 1:
                var_eff[id(eqn.outvars[0])] = min(ins) / 2.0
        if prim == "dot_general":
            cost.add(prim, _dot_flops(eqn), _io_bytes(eqn, var_eff), mult)
        elif prim in ("conv_general_dilated",):
            # rough: 2 * out_elems * kernel_elems_per_out
            out = eqn.outvars[0].aval
            ker = eqn.invars[1].aval
            flops = 2 * int(np.prod(out.shape)) * int(np.prod(ker.shape[2:]))
            cost.add(prim, flops, _io_bytes(eqn, var_eff), mult)
        elif prim == "scan":
            length = eqn.params["length"]
            inner = eqn.params["jaxpr"].jaxpr
            # carries + per-iter slices materialize each iteration
            carry_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            cost.add("scan_io", 0, carry_bytes, mult)
            _walk(inner, cost, mult * length)
        elif prim == "while":
            _walk(eqn.params["body_jaxpr"].jaxpr, cost, mult)
        elif prim == "cond":
            branches = eqn.params["branches"]
            sub = [Cost() for _ in branches]
            for c, b in zip(sub, branches):
                _walk(b.jaxpr, c, 1.0)
            worst = max(sub, key=lambda c: c.flops)
            cost.add("cond", worst.flops, worst.bytes, mult)
        elif prim in ("pjit", "closed_call", "core_call", "remat_call",
                      "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "checkpoint", "remat",
                      "remat2", "custom_vjp_call_fwd"):
            inner = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            if inner is not None:
                _walk(getattr(inner, "jaxpr", inner), cost, mult)
        elif prim in ("gather", "take", "dynamic_slice"):
            cost.add(prim, 0, sum(_aval_bytes(v.aval) for v in eqn.outvars) * 2, mult)
        elif prim in ("dynamic_update_slice", "scatter", "scatter-add", "scatter_add"):
            upd = eqn.invars[-1].aval if hasattr(eqn.invars[-1], "aval") else None
            cost.add(prim, 0, (_aval_bytes(upd) if upd is not None else 0) * 2, mult)
        elif prim in FLOP_ELEMENTWISE:
            cost.add(prim, sum(int(np.prod(v.aval.shape)) for v in eqn.outvars), 0, mult)


def jaxpr_cost(fn, *args) -> Dict[str, float]:
    """Trace ``fn`` with abstract args and return scan-aware global costs."""
    closed = jax.make_jaxpr(fn)(*args)
    cost = Cost()
    _walk(closed.jaxpr, cost, 1.0)
    # params read once per step: count their bytes explicitly (dot operands
    # already include weights per-use; avoid double count — keep dots only)
    return {"flops": cost.flops, "bytes": cost.bytes, "by_prim": cost.by_prim}
