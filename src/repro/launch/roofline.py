"""Roofline-term derivation from compiled dry-run artifacts.

Three terms (seconds), per (arch × shape × mesh):

    compute    = HLO_FLOPs / (chips · peak_FLOPs)
    memory     = HLO_bytes / (chips · HBM_bw)
    collective = Σ collective_bytes / (chips · link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
are parsed out of the compiled HLO text (GSPMD-inserted all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute result shapes).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"^\s*(?:%?[\w.\-]+\s*=\s*)?"
    r"((?:\(|\w+\[)[^)]*?)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M,
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt or ""):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind.

    Result bytes ≈ bytes landing in each device's memory for that op — a
    device-level proxy for link traffic (all-gather result == gathered size;
    reduce-scatter we take the larger operand side by parsing the line's
    leading tuple/shape, which for RS is the input).  ``-start/-done`` async
    pairs are counted once (the ``-done`` line repeats the shape but not the
    opening paren pattern with operands in current HLO; we dedupe by line).
    """
    out: Dict[str, int] = {}
    seen = set()
    for m in _COLL_RE.finditer(hlo_text):
        line_start = hlo_text.rfind("\n", 0, m.start()) + 1
        line = hlo_text[line_start: hlo_text.find("\n", m.start())]
        if "-done(" in line:
            continue
        if line in seen:
            continue
        seen.add(line)
        kind = m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(line.split(kind)[0])
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int
    model_flops: float
    # weights are TP-sharded over "model" but REPLICATED over "data"/"pod":
    # each data replica streams its own copy, so per-chip weight traffic is
    # w_bytes/model_shards, not w_bytes/chips.  ``weight_stream_bytes`` is the
    # total weight bytes read per step (× read count); ``model_shards`` the TP
    # degree.  hbm_bytes already contains w_bytes once (÷chips downstream);
    # the correction adds the replicated re-reads.
    weight_stream_bytes: float = 0.0
    model_shards: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        extra = 0.0
        if self.model_shards and self.model_shards < self.chips:
            extra = self.weight_stream_bytes * (
                self.chips / self.model_shards - 1.0)
        return (self.hbm_bytes + extra) / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """useful work time / achievable step time (higher is better)."""
        t_star = self.model_flops / (self.chips * PEAK_FLOPS)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_star / max(t_bound, 1e-12)

    def to_dict(self) -> Dict:
        return {
            "weight_stream_bytes": self.weight_stream_bytes,
            "model_shards": self.model_shards,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def weight_stream_bytes(shape_tree) -> float:
    """Bytes to stream every weight once (int4-packed uint8 = 1 B/packed)."""
    import jax

    total = 0.0
    for leaf in jax.tree_util.tree_leaves(shape_tree):
        n = 1
        for d in leaf.shape:
            n *= d
        item = leaf.dtype.itemsize
        if leaf.dtype.kind == "f":
            item = min(item, 2)
        total += n * item
    return total


def count_params(shape_tree) -> Tuple[int, int]:
    """(total_param_count, embed_param_count) from a shape tree."""
    import jax

    total = embed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shape_tree)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if "embed/table" in ps or "lm_head" in ps:
            embed += n
    return total, embed


def model_flops_estimate(cfg, shape, n_params: int, n_embed: int) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode), N = active non-embed."""
    n = n_params - n_embed
    if cfg.moe is not None:
        e = cfg.moe
        # expert params scale by top_k/num_experts when active
        expert_per_layer = 3 * cfg.d_model * e.d_expert * e.num_experts
        layers = cfg.num_layers
        inactive = expert_per_layer * layers * (1 - e.top_k / e.num_experts)
        n = n - inactive
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n * tokens
