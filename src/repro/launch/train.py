"""Training driver: config → mesh → sharded train loop with fault tolerance.

Features (designed for 1000+ nodes, exercised here single-process):
- resume-from-latest (atomic checkpoints, counter-based data pipeline);
- checkpoint-on-SIGTERM (preemption);
- per-step deadline watchdog → straggler/hang detection (on a real cluster
  this triggers the backup-replica path; here it logs and checkpoints);
- elastic restore: checkpoints re-lay-out onto whatever mesh the restart
  has (see CheckpointManager.restore(shardings=...)).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt [--smoke]
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager, install_sigterm_checkpoint
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.mesh import make_local_mesh
from repro.models import api
from repro.optim import adamw
from repro.sharding import rules
from repro.train.trainer import make_train_step


class StepWatchdog:
    """Flags steps exceeding a deadline (straggler / hang detection)."""

    def __init__(self, deadline_s: float = 300.0):
        self.deadline = deadline_s
        self.slow_steps = 0

    def observe(self, dt: float, step: int) -> bool:
        if dt > self.deadline:
            self.slow_steps += 1
            print(f"[watchdog] step {step} took {dt:.1f}s "
                  f"(deadline {self.deadline}s) — straggler suspected")
            return True
        return False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true", help="use reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10), microbatch=args.microbatch,
        grad_compression=args.grad_compression,
    )
    mesh = make_local_mesh()
    data = SyntheticTokens(DataConfig(
        seed=0, vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch), cfg)

    params = api.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params, tc)
    start_step = 0

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and mgr.latest_step() is not None:
        (params, opt), meta = mgr.restore((params, opt))
        start_step = int(meta["step"])
        print(f"[resume] restored step {start_step} from {mgr.dir}")

    step_fn = jax.jit(make_train_step(cfg, tc, backend="xla"))
    watchdog = StepWatchdog(deadline_s=600.0)

    state = {"params": params, "opt": opt, "step": start_step}
    if mgr:
        install_sigterm_checkpoint(
            lambda: mgr.save(state["step"], (state["params"], state["opt"]),
                             {"reason": "sigterm"})
        )

    losses = []
    with mesh:
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = data.batch_at(step)
            params, opt, metrics = step_fn(params, opt, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            state.update(params=params, opt=opt, step=step + 1)
            dt = time.time() - t0
            watchdog.observe(dt, step)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.2f}  {dt:.2f}s",
                      flush=True)
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, (params, opt), {"loss": loss})
    if mgr:
        mgr.save(args.steps, (params, opt), {"loss": losses[-1]})
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
