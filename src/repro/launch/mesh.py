"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before calling them.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod (v5e), ×2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
