"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers, GSPMD-
partitions, and compiles — and extract its roofline terms — without touching
real hardware.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k --mesh single --out results/
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are cached as JSON per cell; reruns skip completed cells.
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax locks
# the device count on first init, so this MUST precede every other import.
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import QuantConfig, SHAPES, SHAPES_BY_NAME, TrainConfig
from repro.core.apply import quantize_params
from repro.launch import hlo_analysis as HA
from repro.launch import jaxpr_cost as JC
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.optim import adamw
from repro.sharding import hints
from repro.sharding import rules
from repro.train.trainer import make_train_step

ASSIGNED = ARCH_IDS[:10]  # the 10 assigned archs (codellama-* are extras)


def _abstract(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree,
        is_leaf=lambda l: isinstance(l, jax.ShapeDtypeStruct),
    )


def build_cell(arch: str, shape_name: str, mesh, *, quantized: bool = True,
               train_cfg: TrainConfig | None = None, kv_quant: bool = False):
    """Returns (fn, example_args_shapes, in_shardings, out_shardings, meta)."""
    cfg = get_config(arch)
    if kv_quant:
        cfg = cfg.with_(kv_quant=True)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = api.supports_shape(cfg, shape)
    if not ok:
        raise SkipCell(why)
    # microbatch so per-device live activations fit HBM: one sample per data
    # row per microstep (global/16 grad-accum steps)
    tc = train_cfg or TrainConfig(
        remat="block", microbatch=max(1, shape.global_batch // 16)
    )
    tc_micro = tc.microbatch
    backend = "xla"  # CPU-lowerable quantized matmul; pallas on real TPU

    def named(spec_tree):
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.tree.map(
            lambda sp: NamedSharding(mesh, sp), spec_tree,
            is_leaf=lambda x: isinstance(x, PartitionSpec),
        )

    params_shape = jax.eval_shape(lambda: api.init_model(jax.random.PRNGKey(0), cfg))
    batch_shape = api.input_specs(cfg, shape)
    bspecs = rules.batch_specs(batch_shape, mesh)

    if shape.kind == "train":
        pspecs = rules.param_specs(params_shape, mesh, cfg)
        opt_shape = jax.eval_shape(lambda p: adamw.init_opt_state(p, tc), params_shape)
        ospecs = rules.opt_specs(opt_shape, pspecs, mesh)
        step = make_train_step(cfg, tc, backend=backend)
        fn = jax.jit(
            step,
            in_shardings=named((pspecs, ospecs, bspecs)),
            out_shardings=named((pspecs, ospecs)) + (None,),
            donate_argnums=(0, 1),
        )
        args = (params_shape, opt_shape, batch_shape)
        raw_fn = step
        meta = {"step": "train_step"}
    else:
        if quantized:
            qshape = jax.eval_shape(
                lambda p: quantize_params(p, cfg, QuantConfig())[0], params_shape
            )
        else:
            qshape = params_shape
        pspecs = rules.param_specs(qshape, mesh, cfg)
        if shape.kind == "prefill":
            smax = shape.seq_len

            def prefill(params, batch):
                return api.prefill_fn(params, batch, cfg, smax, backend=backend)

            cache_shape = jax.eval_shape(
                lambda p, b: prefill(p, b), qshape, batch_shape
            )[1]
            cspecs = rules.cache_specs_tree(cache_shape, mesh)
            fn = jax.jit(
                prefill,
                in_shardings=named((pspecs, bspecs)),
                out_shardings=named((rules.logits_prefill_spec(
                    mesh, shape.global_batch, cfg.vocab_size), cspecs)),
            )
            args = (qshape, batch_shape)
            raw_fn = prefill
            meta = {"step": "prefill_step"}
        else:  # decode
            cache_shape = api.cache_specs(cfg, shape)
            cspecs = rules.cache_specs_tree(cache_shape, mesh)

            def serve(params, cache, batch):
                logits, new_cache = api.decode_fn(params, batch, cache, cfg,
                                                  backend=backend)
                return logits, new_cache

            fn = jax.jit(
                serve,
                in_shardings=named((pspecs, cspecs, bspecs)),
                out_shardings=named((rules.logits_decode_spec(
                    mesh, shape.global_batch, cfg.vocab_size), cspecs)),
                donate_argnums=(1,),
            )
            args = (qshape, cache_shape, batch_shape)
            raw_fn = serve
            meta = {"step": "serve_step"}
    meta.update(arch=arch, shape=shape_name, quantized=quantized and shape.kind != "train")
    return fn, args, meta, cfg, shape, params_shape, raw_fn, tc_micro


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             *, force: bool = False, quantized: bool = True, tag: str = "",
             kv_quant: bool = False) -> dict:
    name = f"{arch}__{shape_name}__{mesh_kind}" + (f"__{tag}" if tag else "")
    out_path = out_dir / f"{name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        (fn, args, meta, cfg, shape, params_shape, raw_fn,
         tc_micro) = build_cell(
            arch, shape_name, mesh, quantized=quantized, kv_quant=kv_quant
        )
        rec.update(meta)
        with mesh, hints.hint_mesh(mesh):
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            # scan-aware analytic cost (cost_analysis counts loop bodies once)
            jc = JC.jaxpr_cost(raw_fn, *args)
        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            }
        except Exception as e:  # backend may not support it
            mem_d = {"error": str(e)}
        # weight-stream correction: weights replicate over data; reads/step:
        # serve/prefill = 1; train ≈ 3 (fwd + remat-fwd + bwd) × microbatches
        if shape.kind == "train":
            w_reads = 3.0 * (tc_micro or 1)
            w_shape_tree = params_shape
        else:
            w_reads = 1.0
            w_shape_tree = args[0]
        wsb = RL.weight_stream_bytes(w_shape_tree) * w_reads
        msize = dict(mesh.shape)["model"]
        hlo = compiled.as_text()
        if os.environ.get("DRYRUN_SAVE_HLO"):
            import gzip
            (out_dir / f"{name}.hlo.txt.gz").write_bytes(
                gzip.compress(hlo.encode()))
        coll = HA.collective_bytes(hlo)  # trip-count-aware (per-device bytes)
        ntot, nemb = RL.count_params(params_shape)
        mf = RL.model_flops_estimate(cfg, shape, ntot, nemb)
        chips = mesh.devices.size
        rl = RL.Roofline(flops=float(jc["flops"]), hbm_bytes=float(jc["bytes"]),
                         coll_bytes=float(sum(coll.values())) * chips,
                         chips=chips, model_flops=mf,
                         weight_stream_bytes=wsb, model_shards=msize)
        rec.update(
            ok=True, lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
            chips=chips,
            cost_xla_per_device={k: cost[k] for k in ("flops", "bytes accessed")
                                 if k in cost},
            cost_jaxpr_global={"flops": jc["flops"], "bytes": jc["bytes"]},
            memory=mem_d, collectives_per_device=coll,
            n_params=ntot, n_embed_params=nemb,
            roofline=rl.to_dict(),
        )
    except SkipCell as e:
        rec.update(ok=True, skipped=True, reason=str(e))
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-3000:])
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--fp16-weights", action="store_true",
                    help="serve cells with unquantized weights (ablation)")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = 0
    for mk in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mk, out_dir, force=args.force,
                               tag=args.tag, kv_quant=args.kv_quant,
                               quantized=not args.fp16_weights)
                status = ("SKIP" if rec.get("skipped")
                          else "OK" if rec.get("ok") else "FAIL")
                extra = ""
                if rec.get("ok") and not rec.get("skipped"):
                    rl = rec["roofline"]
                    extra = (f" bottleneck={rl['bottleneck']}"
                             f" frac={rl['roofline_fraction']:.3f}"
                             f" compile={rec.get('compile_s', '?')}s")
                elif not rec.get("ok"):
                    extra = " " + rec.get("error", "")[:120]
                print(f"[{status}] {a} × {s} × {mk}{extra}", flush=True)
                n_ok += rec.get("ok", False)
                n_fail += not rec.get("ok", False)
    print(f"done: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
