"""Serving driver: FP checkpoint → SmoothQuant+ quantize-on-load →
continuous-batching engine (the paper's vLLM deployment flow).

    PYTHONPATH=src python -m repro.launch.serve --arch codellama-7b --smoke \
        --requests 12 [--no-quant] [--ptq-artifact DIR]

Beyond attention-only decoders the same flow serves hybrid SSM
(``--arch zamba2-7b``: per-layer fixed-rows state next to the paged
attention KV) and encoder-decoder (``--arch whisper-medium``: synthetic
encoder frames per request, deduplicated read-only encoder pages).

``--ptq-artifact DIR`` makes boot load-*or*-quantize: the first run saves the
quantized pytree there; later runs deserialize it and skip calibration + the
α search entirely (a config change invalidates the artifact via its hash).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import QuantConfig
from repro.core.calibration import synthetic_calibration_set
from repro.models import api
from repro.serving.engine import Request, ServingEngine, load_or_quantize


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codellama-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--rate", type=float, default=50.0, help="req/s (Poisson)")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--act-quant", choices=("a16", "a8_prefill"),
                    default="a16",
                    help="activation quantization: a16 (default, bf16/f32 "
                         "activations) or a8_prefill (per-token int8 "
                         "activations on prefill-chunk GEMMs for A8-eligible "
                         "layers; decode stays A16)")
    ap.add_argument("--group-size", type=int, default=None)
    ap.add_argument("--ptq-artifact", default=None,
                    help="dir for the PTQ artifact: save on first boot, "
                         "load (skip calibration + alpha search) after")
    ap.add_argument("--ptq-refresh", action="store_true",
                    help="force re-quantization even if a matching artifact "
                         "exists (use after swapping checkpoints — the "
                         "artifact hash covers configs, not weight values)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV cache page size (tokens)")
    ap.add_argument("--prefill-mode", choices=("bucketed", "slotwise"),
                    default="bucketed")
    ap.add_argument("--max-prefill-tokens", type=int, default=None,
                    help="padded-token budget per engine step (chunked prefill)")
    ap.add_argument("--reservation", choices=("lazy", "worstcase"),
                    default="lazy",
                    help="page reservation: lazy growth + preemption "
                         "(default) or up-front prompt+max_tokens pages")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="KV pool size in pages (default: worst case + trash)")
    ap.add_argument("--prefix-cache", choices=("on", "off"), default="on",
                    help="shared-prefix KV cache: block-hash reuse of full "
                         "prompt pages + suffix-only prefill (default on)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="give every synthetic request this many identical "
                         "leading prompt tokens (a shared system prompt) so "
                         "the prefix cache has something to hit")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded waiting line: submit() sheds load "
                         "(finish_reason='rejected') past this depth")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request total wall budget from arrival; "
                         "expired requests free their pages immediately")
    ap.add_argument("--ttft-deadline-s", type=float, default=None,
                    help="per-request first-token budget from arrival")
    ap.add_argument("--chaos", action="store_true",
                    help="install a seeded FaultPlan firing at every "
                         "injection site and serve non-strict (graceful "
                         "degradation demo: the drain must survive)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the --chaos fault plan")
    ap.add_argument("--no-metrics", action="store_true",
                    help="disable engine observability (timelines, "
                         "histograms, step journal) — the overhead-"
                         "benchmark baseline configuration")
    ap.add_argument("--trace-out", default=None,
                    help="write the served batch's step journal + request "
                         "timelines as Chrome trace_event JSON (open in "
                         "https://ui.perfetto.dev or chrome://tracing)")
    args = ap.parse_args(argv)
    if args.trace_out and args.no_metrics:
        ap.error("--trace-out needs metrics enabled (drop --no-metrics)")

    cfg = get_config(args.arch, smoke=args.smoke).with_(
        act_quant=args.act_quant)
    if not args.no_quant:
        cfg = cfg.with_(dtype="float32")  # PTQ math in f32 at smoke scale
    params = api.init_model(jax.random.PRNGKey(0), cfg)

    if not args.no_quant:
        gs = args.group_size or (16 if args.smoke else 128)
        calib = synthetic_calibration_set(cfg, n_seqs=2, seq_len=24)
        t0 = time.time()
        from repro.core.apply import ptq_matches
        qcfg = QuantConfig(group_size=gs)
        # a present-but-stale artifact still re-quantizes: label the boot by
        # the path load_or_quantize will actually take
        loaded = (args.ptq_artifact is not None and not args.ptq_refresh
                  and ptq_matches(args.ptq_artifact, cfg, qcfg))
        params, rep = load_or_quantize(params, cfg, calib, qcfg,
                                       artifact_dir=args.ptq_artifact,
                                       refresh=args.ptq_refresh)
        mode = "artifact-load" if loaded else "quantize-on-load"
        print(f"[{mode}] alpha={rep.alpha:.2f} "
              f"{rep.fp_bytes/1e6:.1f}MB -> {rep.quant_bytes/1e6:.1f}MB "
              f"in {time.time()-t0:.1f}s")

    fault_plan = None
    if args.chaos:
        from repro.serving.faults import FaultPlan, FaultSpec
        fault_plan = FaultPlan([
            FaultSpec("page_alloc", every=11, times=3),
            FaultSpec("page_grow", prob=0.05, times=3),
            FaultSpec("pool_pressure", step=4, value=2, duration=3),
            FaultSpec("swap_drain", op=0, times=1),
            FaultSpec("swap_corrupt", op=1, times=1),
            FaultSpec("prefix_evict", every=5, times=2),
            FaultSpec("decode_launch", step=6, times=2),
            FaultSpec("prefill_launch", op=2, times=1),
            FaultSpec("fixed_drain", op=0, times=1),
            FaultSpec("enc_evict", op=1, times=1),
        ], seed=args.fault_seed)
    # the token prefix cache is attention-only (the engine rejects it for
    # hybrid SSM / enc-dec configs — see state leaves in serving/engine.py)
    leaves = api.state_leaves(cfg)
    prefix_cache = (args.prefix_cache == "on" and leaves == (api.KV_PAGES,))
    if args.prefix_cache == "on" and not prefix_cache:
        print(f"[note] token prefix cache disabled: {cfg.family} slots carry "
              f"state leaves {leaves}")
    eng = ServingEngine(params, cfg, batch_size=args.batch_size,
                        max_seq=args.max_seq, backend="xla",
                        page_size=args.page_size,
                        num_pages=args.num_pages,
                        prefill_mode=args.prefill_mode,
                        max_prefill_tokens=args.max_prefill_tokens,
                        reservation=args.reservation,
                        prefix_cache=prefix_cache,
                        max_queue=args.max_queue,
                        fault_plan=fault_plan,
                        strict=not args.chaos,
                        metrics=not args.no_metrics)
    rng = np.random.default_rng(0)
    # deadlines are wall-clock budgets from arrival: rebase the synthetic
    # Poisson offsets onto the engine's clock, or every request would look
    # minutes old at its first deadline check
    base = time.perf_counter()
    arrive = base + np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    sys_p = rng.integers(2, cfg.vocab_size,
                         args.shared_prefix_len).astype(np.int32)

    def frames(i):
        # enc-dec requests carry synthetic encoder frames; every third
        # request repeats the first one's audio so the exact-match encoder
        # page cache has something to deduplicate
        if not eng.has_enc:
            return None
        r = np.random.default_rng(1000 + (0 if i % 3 == 0 else i))
        t = 6 + (0 if i % 3 == 0 else i % 5)
        return (r.standard_normal((t, cfg.d_model)) * 0.1).astype(np.float32)

    reqs = [Request(uid=i,
                    prompt=np.concatenate(
                        [sys_p,
                         rng.integers(2, cfg.vocab_size, 10).astype(np.int32)]),
                    max_tokens=args.max_tokens, arrival_t=float(arrive[i]),
                    deadline_s=args.deadline_s,
                    ttft_deadline_s=args.ttft_deadline_s,
                    frames=frames(i))
            for i in range(args.requests)]
    t0 = time.perf_counter()
    accepted = sum(eng.submit(r) for r in reqs)
    eng.run_until_drained()
    dt = time.perf_counter() - t0

    # every stat line below renders engine.metrics_snapshot() — the single
    # structured source for operator reporting (benchmarks read it too)
    snap = eng.metrics_snapshot()
    st = snap["engine"]

    def ms(h, k="p50"):
        return f"{snap['latency'][h][k] * 1e3:.1f}ms"

    print(f"served {st['completed']}/{args.requests} requests "
          f"({accepted} accepted), "
          f"{st['decoded_tokens']} tokens in {dt:.2f}s  "
          f"({st['decoded_tokens'] / dt:.1f} tok/s)")
    if not args.no_metrics:
        print(f"latency: ttft p50={ms('ttft_s')} p99={ms('ttft_s', 'p99')}  "
              f"itl p50={ms('itl_s')} p99={ms('itl_s', 'p99')}  "
              f"e2e p50={ms('e2e_s')} p99={ms('e2e_s', 'p99')}  "
              f"queue-wait p50={ms('queue_wait_s')}  "
              f"swap-stall p50={ms('swap_stall_s')}")
    print(f"lifecycle: rejected={st['rejected']} expired={st['expired']} "
          f"cancelled={st['cancelled']} failed={st['failed']} "
          f"retries={st['retries']} faults_injected={st['faults_injected']}")
    if fault_plan is not None:
        print(f"chaos: fault counters "
              f"{snap['counters'].get('faults_fired_total', {})}")
    pg = snap["pager"]
    print(f"pager: peak concurrency {st['max_active']}/{args.batch_size}, "
          f"{st['grown_pages']} pages grown lazily, "
          f"{st['preemptions']} preemptions "
          f"({st['swapped_out_bytes'] / 1e6:.1f}MB swapped out, "
          f"of which {st['swapped_fixed_bytes'] / 1e6:.1f}MB fixed-rows "
          f"state, {st['swapped_in_bytes'] / 1e6:.1f}MB back in); "
          f"free={pg['free_pages']}/{pg['total_pages']} "
          f"counts={pg['counts']}")
    if eng.has_enc:
        print(f"encoder pages: {st['enc_encodes']} encodes, "
              f"{st['enc_hits']} exact-match hits")
    if prefix_cache:
        hit = st["prefix_hits"] / max(st["admitted"], 1)
        print(f"prefix-cache: hit-rate {hit:.0%} "
              f"({st['prefix_hits']}/{st['admitted']} admissions, "
              f"{st['prefix_matched_tokens']} prompt tokens served from "
              f"cache), {st['pages_shared']} pages shared, "
              f"{st['pages_inserted']} inserted, "
              f"{st['pages_evicted']} evicted, "
              f"{st['cow_copies']} copy-on-writes")
    if args.trace_out:
        from repro.serving.trace import write_chrome_trace
        obj = write_chrome_trace(args.trace_out, eng.trace,
                                 n_slots=args.batch_size)
        print(f"trace: {len(obj['traceEvents'])} events -> {args.trace_out} "
              f"(open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
