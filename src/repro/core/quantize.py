"""Group-wise 4-bit asymmetric RTN quantization (SmoothQuant+ §2.1, eq. 1).

Conventions
-----------
A linear layer weight is ``W[Ci, Co]`` (input channels × output channels), so
``Y = X @ W``.  Quantization groups are *along the input-channel (contraction)
axis*: group ``g`` covers rows ``[g*G, (g+1)*G)`` and has one ``scale``/``zero``
per output channel, i.e. ``scales[Ci//G, Co]``.

Packed storage: two int4 codes per uint8, packed along the input-channel axis
in a *group-split* layout chosen for the TPU kernel: within each quantization
group of ``G`` rows, packed row ``r`` (``r < G//2``) holds code
``q[g*G + r, o]`` in the low nibble and ``q[g*G + G//2 + r, o]`` in the high
nibble.  Unpacking a group is then ``concat([lo, hi], axis=0)`` — a sublane
concatenation, with no row interleave — which lowers cleanly on TPU and keeps
each group contiguous in VMEM next to its ``scales``/``zeros`` row.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

NBITS = 4
QMAX = (1 << NBITS) - 1  # 15
DEFAULT_GROUP_SIZE = 128


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("packed", "scales", "zeros"),
    meta_fields=("a8",),
)
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """A group-wise int4-quantized weight, packed 2 codes / uint8.

    Leading dims are first-class: a *stacked* quantized leaf (layer stacks
    ``[L, ...]``, MoE experts ``[E, Ci, Co]``, MLA absorbed heads
    ``[H, Ci, Co]``) carries the extra dims on all three arrays, quantized
    independently along each trailing ``[Ci, Co]`` plane — ``lax.scan`` and
    the EP sharding rules treat the leaves like any stacked fp weight.

    Attributes:
      packed: uint8[*lead, Ci//2, Co] — packed int4 codes (group-split rows).
      scales: dtype[*lead, Ci//G, Co] — per-group, per-out-channel step Δ.
      zeros:  dtype[*lead, Ci//G, Co] — per-group, per-out-channel zero point
              (stored in the *float* domain as ``zero_code`` so dequant is
              ``(q - zeros) * scales``).
      a8:     static (non-traced) A8 eligibility flag: calibration found this
              layer's post-smoothing inputs safe for per-token int8
              activations.  ``ops.w4a16_matmul``/``w4a16_grouped_matmul``
              only take the int8×int4 path when it is True; being tree
              *metadata*, a flip retraces rather than recompiles-per-step,
              and ``lax.scan`` over stacked leaves carries it unchanged.
    """

    packed: jax.Array
    scales: jax.Array
    zeros: jax.Array
    a8: bool = True

    @property
    def shape(self) -> Tuple[int, ...]:
        return (*self.packed.shape[:-2], self.packed.shape[-2] * 2, self.packed.shape[-1])

    @property
    def ndim(self) -> int:
        return self.packed.ndim

    @property
    def group_size(self) -> int:
        return (self.packed.shape[-2] * 2) // self.scales.shape[-2]

    @property
    def dtype(self):
        return self.scales.dtype

    def __getitem__(self, idx) -> "QuantizedTensor":
        """Index/slice *leading* (stack) dims, e.g. ``qt[e]`` → one expert's
        2-D tensor.  The packed/group planes themselves are not indexable."""
        if self.packed.ndim < 3:
            raise IndexError("QuantizedTensor[...] indexes leading stack dims "
                             "only; this tensor is 2-D")
        return QuantizedTensor(
            packed=self.packed[idx], scales=self.scales[idx],
            zeros=self.zeros[idx], a8=self.a8)

    def nbytes_quant(self) -> int:
        return (
            self.packed.size * self.packed.dtype.itemsize
            + self.scales.size * self.scales.dtype.itemsize
            + self.zeros.size * self.zeros.dtype.itemsize
        )


def _check_nd(w: jax.Array) -> None:
    if w.ndim < 2:
        raise ValueError(f"expected >=2-D weight, got shape {w.shape}")


def compute_qparams(
    w: jax.Array, group_size: int = DEFAULT_GROUP_SIZE
) -> Tuple[jax.Array, jax.Array]:
    """Per-(group, out-channel) asymmetric min/max qparams (eq. 1).

    Returns (scales, zeros), each ``[Ci//G, Co]`` in ``w.dtype``'s compute
    precision (f32 internally, cast back).
    """
    _check_nd(w)
    *lead, ci, co = w.shape
    if ci % group_size != 0:
        raise ValueError(f"Ci={ci} not divisible by group_size={group_size}")
    g = ci // group_size
    wf = w.astype(jnp.float32).reshape(*lead, g, group_size, co)
    wmax = jnp.max(wf, axis=-2)
    wmin = jnp.min(wf, axis=-2)
    scales = (wmax - wmin) / QMAX
    # Avoid 0 step for constant groups.
    scales = jnp.where(scales <= 0, jnp.ones_like(scales), scales)
    # Eq. 1 clamps the *codes* to [0, 2^N-1]; Z itself is unclamped (we store
    # it in float alongside the scales, so offset-only groups stay exact).
    zeros = jnp.round(-wmin / scales)
    return scales, zeros


def quantize_codes(
    w: jax.Array,
    scales: jax.Array,
    zeros: jax.Array,
    group_size: int = DEFAULT_GROUP_SIZE,
) -> jax.Array:
    """RTN: map ``w`` to int codes in [0, 15].  Returns uint8[..., Ci, Co] (unpacked)."""
    *lead, ci, co = w.shape
    g = ci // group_size
    wf = w.astype(jnp.float32).reshape(*lead, g, group_size, co)
    q = jnp.round(wf / scales[..., None, :]) + zeros[..., None, :]
    q = jnp.clip(q, 0, QMAX).astype(jnp.uint8)
    return q.reshape(*lead, ci, co)


def pack_codes(q: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> jax.Array:
    """Pack uint8 codes (0..15) into uint8[..., Ci//2, Co], group-split layout."""
    *lead, ci, co = q.shape
    if ci % group_size != 0 or group_size % 2 != 0:
        raise ValueError(f"Ci={ci} / group_size={group_size} incompatible")
    h = group_size // 2
    qg = q.reshape(*lead, ci // group_size, 2, h, co)
    return (qg[..., 0, :, :] | (qg[..., 1, :, :] << 4)).astype(jnp.uint8).reshape(
        *lead, ci // 2, co
    )


def unpack_codes(packed: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> jax.Array:
    """Inverse of :func:`pack_codes` → uint8[..., Ci, Co]."""
    *lead, ci2, co = packed.shape
    h = group_size // 2
    pg = packed.reshape(*lead, ci2 // h, h, co)
    lo = pg & 0x0F
    hi = (pg >> 4) & 0x0F
    return jnp.concatenate([lo, hi], axis=-2).reshape(*lead, ci2 * 2, co)


def quantize(
    w: jax.Array,
    group_size: int = DEFAULT_GROUP_SIZE,
    dtype: jnp.dtype | None = None,
) -> QuantizedTensor:
    """Group-wise asymmetric 4-bit RTN quantization of ``W[Ci, Co]``."""
    _check_nd(w)
    dtype = dtype or w.dtype
    scales, zeros = compute_qparams(w, group_size)
    q = quantize_codes(w, scales, zeros, group_size)
    return QuantizedTensor(
        packed=pack_codes(q, group_size),
        scales=scales.astype(dtype),
        zeros=zeros.astype(dtype),
    )


def dequantize(qt: QuantizedTensor, dtype: jnp.dtype | None = None) -> jax.Array:
    """Ŵ = (q − zero) · Δ, back to ``[..., Ci, Co]``."""
    dtype = dtype or qt.dtype
    q = unpack_codes(qt.packed, qt.group_size).astype(jnp.float32)
    *lead, ci, co = q.shape
    g = qt.scales.shape[-2]
    qg = q.reshape(*lead, g, ci // g, co)
    w = (qg - qt.zeros[..., None, :].astype(jnp.float32)) * qt.scales[
        ..., None, :
    ].astype(jnp.float32)
    return w.reshape(*lead, ci, co).astype(dtype)


def fake_quantize(
    w: jax.Array, group_size: int = DEFAULT_GROUP_SIZE
) -> jax.Array:
    """quantize→dequantize round trip in one shot (used by the α search)."""
    _check_nd(w)
    *lead, ci, co = w.shape
    if ci % group_size != 0 or ci < group_size:
        raise ValueError(f"Ci={ci} incompatible with group_size={group_size}")
    g = ci // group_size
    wf = w.astype(jnp.float32).reshape(*lead, g, group_size, co)
    wmax = jnp.max(wf, axis=-2, keepdims=True)
    wmin = jnp.min(wf, axis=-2, keepdims=True)
    scales = (wmax - wmin) / QMAX
    scales = jnp.where(scales <= 0, jnp.ones_like(scales), scales)
    zeros = jnp.round(-wmin / scales)
    q = jnp.clip(jnp.round(wf / scales) + zeros, 0, QMAX)
    return ((q - zeros) * scales).reshape(*lead, ci, co).astype(w.dtype)


# --------------------------------------------------- A8 activations -------
# The W4A8 prefill path (FPTQ / arxiv 2311.05161 on top of SmoothQuant+'s
# smoothing): activations quantize per *token row* to symmetric int8 right
# before the GEMM, the kernel contracts int8×int4→int32 on the MXU, and the
# per-(token, group) rescale restores the float domain.  These helpers define
# the quantization semantics once — the Pallas kernels, the XLA oracles, and
# the calibration-time eligibility metric all share them.

ACT_QMAX = 127  # symmetric int8


def quantize_acts_per_token(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-token symmetric int8 activation quantization.

    ``x[..., Ci]`` → ``(codes int8[..., Ci], scales f32[..., 1])`` with
    ``x ≈ codes * scales``.  Symmetric per-row scaling never clips the row
    max; the error is pure rounding, which is what the calibration-time
    eligibility metric measures.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scales = jnp.maximum(amax, 1e-8) / ACT_QMAX
    codes = jnp.clip(jnp.round(xf / scales), -ACT_QMAX, ACT_QMAX).astype(
        jnp.int8
    )
    return codes, scales


def a8_roundtrip_error(x: jax.Array) -> jax.Array:
    """Worst per-token relative RMS error of the int8 activation round trip.

    The per-layer A8-eligibility statistic: rows whose magnitude is dominated
    by a few surviving outlier channels lose most of their levels and score
    high; post-smoothing rows score ~``1/(127·√12)``.  Returns a scalar —
    ``max`` over token rows, so one bad row disqualifies the layer.
    """
    xf = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    codes, scales = quantize_acts_per_token(xf)
    err = codes.astype(jnp.float32) * scales - xf
    num = jnp.sqrt(jnp.mean(err * err, axis=-1))
    den = jnp.sqrt(jnp.mean(xf * xf, axis=-1))
    return jnp.max(num / jnp.maximum(den, 1e-8))


def quantization_loss(
    w: jax.Array,
    x_stat: jax.Array,
    group_size: int = DEFAULT_GROUP_SIZE,
) -> jax.Array:
    """Activation-weighted quantization loss  E ≈ ||diag(x)·(W − Ŵ)||²  (eq. 4).

    ``x_stat[Ci]`` is a per-input-channel activation magnitude statistic
    (channel max over the calibration set); using it instead of the full X
    matrix makes the whole-model loss evaluation O(params) per α instead of
    O(calibration tokens × params), while preserving the outlier-amplification
    structure the paper exploits.
    """
    err = (w - fake_quantize(w, group_size)).astype(jnp.float32)
    return jnp.sum((err * x_stat.astype(jnp.float32)[..., :, None]) ** 2)
