"""Global smoothing-strength (alpha) grid search — SmoothQuant+ §2.2/§3.1.3.

Unlike AWQ's per-layer search, a SINGLE alpha is searched for the WHOLE model
by minimizing the total activation-weighted quantization loss

    E(alpha) = Σ_linears || diag(x̂) (W_s − Q(W_s)) ||²,   x̂ = stats / s

over a grid (default 0→1 step 0.05, the paper's recommendation).  Because the
loss is evaluated directly on (smoothed weights, smoothed stats) it accounts
for the whole model at once — no per-layer error accumulation — and one grid
point costs one fake-quant sweep of the weights (this is why the paper's
search is ~5× faster than AWQ's).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import smoothing as SM
from repro.core.calibration import StatsCollector
from repro.core.quantize import fake_quantize


@dataclasses.dataclass
class SearchResult:
    alpha: float
    loss: float
    losses: Dict[float, float]          # full grid → loss curve (paper Tab. 4)


def _group_quant_loss(
    params, cfg: ModelConfig, col: StatsCollector, group: SM.Group,
    alpha: float, group_size: int,
) -> float:
    """Activation-weighted loss for one group at one alpha (eq. 4 proxy)."""
    act = SM.assemble_stats(col, group.stats_block, group.stats_sub)
    s = SM.compute_group_s(params, cfg, col, group, alpha)
    x_hat = jnp.asarray(act / s)        # smoothed activation stats
    total = 0.0
    for wp in group.weights:
        w = SM.tget(params, wp).astype(jnp.float32)
        sal = SM._align(s, w)
        ws = w * sal                    # smoothed weight
        err = (ws - fake_quantize(ws, group_size)).astype(jnp.float32)
        extra = w.ndim - 1 - x_hat.ndim
        xb = x_hat.reshape(*x_hat.shape[:-1], *([1] * extra), x_hat.shape[-1], 1)
        total += float(jnp.sum((err * xb) ** 2))
    return total


def model_quant_loss(
    params, cfg: ModelConfig, col: StatsCollector, alpha: float,
    group_size: int = 128,
) -> float:
    total = 0.0
    for g in SM.smoothing_groups(cfg):
        try:
            total += _group_quant_loss(params, cfg, col, g, alpha, group_size)
        except KeyError:
            continue
    return total


def search_alpha(
    params,
    cfg: ModelConfig,
    col: StatsCollector,
    *,
    step: float = 0.05,
    group_size: int = 128,
    verbose: bool = False,
) -> SearchResult:
    """Grid-search alpha ∈ {0, step, …, 1} minimizing the whole-model loss."""
    grid = np.round(np.arange(0.0, 1.0 + 1e-9, step), 10)
    losses: Dict[float, float] = {}
    for a in grid:
        losses[float(a)] = model_quant_loss(params, cfg, col, float(a), group_size)
        if verbose:
            print(f"  alpha={a:.2f}  loss={losses[float(a)]:.6f}")
    best = min(losses, key=losses.get)
    return SearchResult(alpha=best, loss=losses[best], losses=losses)
