"""End-to-end SmoothQuant+ PTQ pipeline:  calibrate → search α → smooth →
group-wise int4-quantize.  Mirrors the paper's vLLM flow: the user hands us
FP16/bf16 params; quantization happens during placement (quantize-on-load),
so only packed int4 + scales ever reside in device memory for linear weights.

Quantize-once / serve-many: :func:`save_ptq` persists the quantized pytree +
:class:`PTQReport` as an on-disk artifact (``checkpoint/manager.py``) keyed by
a config fingerprint; :func:`load_ptq` boots straight from it — zero
calibration batches, zero α-search steps — refusing stale artifacts.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import calibration as C
from repro.core import search as S
from repro.core import smoothing as SM
from repro.core.quantize import QuantizedTensor, quantize


@dataclasses.dataclass
class PTQReport:
    alpha: float
    search_loss: float
    loss_curve: Dict[float, float]
    quantized_paths: List[Tuple[Any, ...]]
    fp_bytes: int
    quant_bytes: int
    # W4A8 prefill: per-weight-path ("/"-joined) eligibility flag and the
    # post-smoothing per-token int8 round-trip error that decided it
    a8_eligibility: Dict[str, bool] = dataclasses.field(default_factory=dict)
    a8_errors: Dict[str, float] = dataclasses.field(default_factory=dict)


def quantizable_paths(cfg: ModelConfig) -> List[Tuple[Any, ...]]:
    """All weights named by the smoothing group table (the PTQ target set)."""
    out: List[Tuple[Any, ...]] = []
    for g in SM.smoothing_groups(cfg):
        out.extend(g.weights)
    return out


def _fit_group(ci: int, group_size: int) -> int:
    """Largest power-of-two divisor of ``ci`` at most ``group_size``."""
    g = group_size
    while g > 2 and ci % g != 0:
        g //= 2
    return max(g, 2)


def _mla_absorbed_quantize(w: jax.Array, cfg: ModelConfig, qcfg: QuantConfig):
    """Stacked int4 absorbed-form projections derived from a *smoothed fp*
    ``wkv_b[*, r, H*(nope+v)]``.

    Absorbed MLA decode contracts the two ``wkv_b`` halves along *different*
    axes: ``q_lat = q_nope · w_k`` sums over the nope head dim while
    ``out = o_lat · w_v`` sums over the latent rank r — and group quantization
    lives on the contraction axis.  So the k-half is stored transposed
    (``wk_t[H, nope, r]``, groups along nope) and the v-half head-stacked
    (``wv[H, r, v]``, groups along r); heads ride the grouped kernel's expert
    grid axis.  The extra int4 copy of the k-half costs ~1/8 of one bf16
    copy — the price of never re-inflating ``wkv_b`` in HBM at decode."""
    m = cfg.mla
    h = cfg.num_heads
    wr = w.reshape(*w.shape[:-1], h, m.qk_nope_head_dim + m.v_head_dim)
    wk = jnp.moveaxis(wr[..., : m.qk_nope_head_dim], -3, -1)  # [*, H, n, r]
    wv = jnp.swapaxes(wr[..., m.qk_nope_head_dim:], -3, -2)   # [*, H, r, v]
    gk = _fit_group(m.qk_nope_head_dim, qcfg.group_size)
    gv = _fit_group(m.kv_lora_rank, qcfg.group_size)
    return {
        "wk_t": quantize(wk, group_size=gk, dtype=cfg.jdtype),
        "wv": quantize(wv, group_size=gv, dtype=cfg.jdtype),
    }


def derive_a8_eligibility(
    col: C.StatsCollector, cfg: ModelConfig, qcfg: QuantConfig
) -> Tuple[Dict[Tuple[Any, ...], bool], Dict[str, float]]:
    """Per-weight-path W4A8 eligibility from *post-smoothing* activation stats.

    Eligibility is a property of a layer's input activations, and every
    weight in a smoothing group shares one input — so the decision is made
    per group, keyed by the group's collector stat key.  The worst per-token
    int8 round-trip error seen for that key — max over calibration batches
    AND stacked-layer depth (the flag is static per stacked tensor, so one
    bad layer vetoes its whole stack) — must stay within
    ``qcfg.a8_threshold``.  Groups with no recorded stats (path absent from
    this layout) are conservatively ineligible.

    Returns ``(path → bool, "/"-joined path → worst error)``.
    """
    amap: Dict[Tuple[Any, ...], bool] = {}
    errors: Dict[str, float] = {}
    for g in SM.smoothing_groups(cfg):
        errs = [v for (blk, _lidx, sub), v in col.a8_err.items()
                if blk == g.stats_block and sub == g.stats_sub]
        worst = max(errs) if errs else float("inf")
        ok = bool(worst <= qcfg.a8_threshold)
        for wp in g.weights:
            amap[wp] = ok
            errors["/".join(map(str, wp))] = worst
    return amap, errors


def _tree_a8_flags(qparams, paths) -> Dict[str, bool]:
    """Snapshot the static ``a8`` flags actually stamped on the tree — the
    source of truth for reports and the artifact (an MLA absorbed pair moves
    in step, so it reports as a single flag)."""
    out: Dict[str, bool] = {}
    for p in paths:
        node = SM.tget(qparams, p)
        if isinstance(node, QuantizedTensor):
            out["/".join(map(str, p))] = bool(node.a8)
        elif isinstance(node, dict):
            out["/".join(map(str, p))] = bool(
                all(v.a8 for v in node.values()))
    return out


def quantize_params(
    params, cfg: ModelConfig, qcfg: QuantConfig, *,
    a8_map: Optional[Dict[Tuple[Any, ...], bool]] = None,
) -> Tuple[Any, List[Tuple[Any, ...]], int, int]:
    """Replace every quantizable linear weight with a QuantizedTensor.

    MLA layers additionally grow ``mixer/wkv_b_absorbed`` — stacked int4
    absorbed-form decode projections (see :func:`_mla_absorbed_quantize`), so
    no serving path ever needs to dequantize ``wkv_b`` wholesale.

    ``a8_map`` (from :func:`derive_a8_eligibility`) stamps the static ``a8``
    flag on each QuantizedTensor; paths missing from the map — including the
    absorbed MLA tensors, whose latent-domain inputs are never calibrated —
    are marked ineligible.  ``a8_map=None`` (RTN baseline, direct calls)
    leaves the permissive default ``a8=True``."""
    fp_bytes = quant_bytes = 0
    done = []
    for wp in quantizable_paths(cfg):
        try:
            w = SM.tget(params, wp)
        except (KeyError, TypeError):
            continue  # block absent in this layout (e.g. no hybrid tail)
        qt = quantize(w, group_size=qcfg.group_size, dtype=cfg.jdtype)
        if a8_map is not None:
            qt = dataclasses.replace(qt, a8=bool(a8_map.get(wp, False)))
        params = SM.tset(params, wp, qt)
        fp_bytes += w.size * 2
        quant_bytes += qt.nbytes_quant()
        done.append(wp)
        if cfg.mla is not None and wp[-2:] == ("wkv_b", "w"):
            ab = _mla_absorbed_quantize(w, cfg, qcfg)
            ap = wp[:-2] + ("wkv_b_absorbed",)
            if a8_map is not None:
                ab = {k: dataclasses.replace(v, a8=bool(a8_map.get(ap, False)))
                      for k, v in ab.items()}
            params = SM.tset(params, ap, ab, create=True)
            quant_bytes += ab["wk_t"].nbytes_quant() + ab["wv"].nbytes_quant()
            done.append(ap)
    return params, done, fp_bytes, quant_bytes


def smoothquant_plus(
    params,
    cfg: ModelConfig,
    calibration_batches: Iterable[Dict[str, jax.Array]],
    qcfg: QuantConfig = QuantConfig(),
    *,
    step: float = 0.05,
    verbose: bool = False,
) -> Tuple[Any, PTQReport]:
    """The full SmoothQuant+ recipe (paper §3.1.3).

    1. calibrate: channel max |X| per linear input on the calibration set;
    2. grid-search a single global α (step 0.05) minimizing whole-model loss;
    3. smooth (W ← diag(s)W, provider ← provider/s) — mathematically exact;
    4. group-wise 4-bit RTN quantization of the smoothed linear weights.

    Beyond-paper W4A8 addendum: a second calibration pass over the *smoothed*
    model measures what per-token int8 activation quantization would cost
    each layer post-smoothing, and layers over ``qcfg.a8_threshold`` are
    flagged A16-only (see :func:`derive_a8_eligibility`).  The flags ride the
    QuantizedTensors into the artifact, so a served ``act_quant="a8_prefill"``
    engine needs no calibration data of its own.
    """
    batches = list(calibration_batches)  # consumed twice (pre + post smooth)
    col = C.collect_stats(params, cfg, batches)
    if qcfg.alpha is not None:
        res = S.SearchResult(alpha=qcfg.alpha,
                             loss=S.model_quant_loss(params, cfg, col, qcfg.alpha,
                                                     qcfg.group_size),
                             losses={})
    else:
        res = S.search_alpha(params, cfg, col, step=step,
                             group_size=qcfg.group_size, verbose=verbose)
    smoothed, _ = SM.smooth_model(params, cfg, col, res.alpha)
    if not qcfg.enabled:
        return smoothed, PTQReport(res.alpha, res.loss, res.losses, [], 0, 0)
    col2 = C.collect_stats(smoothed, cfg, batches)
    a8_map, a8_errors = derive_a8_eligibility(col2, cfg, qcfg)
    qparams, paths, fpb, qb = quantize_params(smoothed, cfg, qcfg,
                                              a8_map=a8_map)
    return qparams, PTQReport(
        alpha=res.alpha, search_loss=res.loss, loss_curve=res.losses,
        quantized_paths=paths, fp_bytes=fpb, quant_bytes=qb,
        a8_eligibility=_tree_a8_flags(qparams, paths),
        a8_errors=a8_errors,
    )


def rtn_baseline(params, cfg: ModelConfig, qcfg: QuantConfig = QuantConfig()):
    """Paper baseline: plain group-wise RTN, no smoothing."""
    return quantize_params(params, cfg, qcfg)[0]


# ------------------------------------------------------- PTQ artifact I/O ---
class StalePTQArtifactError(ValueError):
    """The artifact was produced under a different (model, quant) config."""


def ptq_fingerprint(cfg: ModelConfig, qcfg: QuantConfig) -> str:
    """Config hash stored in / checked against the artifact: any change to
    the model or quantization config invalidates saved artifacts, so a stale
    artifact can never be silently served.

    ``act_quant`` is normalized out: it is a serving-time routing choice —
    the artifact (weights + eligibility flags) is identical either way, so
    one artifact serves both A16 and A8-prefill engines.  ``a8_threshold``
    (a QuantConfig field) *does* participate: it changes the baked-in flags.
    """
    return hashlib.sha256(
        repr((cfg.with_(act_quant="a16"), qcfg)).encode()).hexdigest()[:16]


def has_ptq(directory) -> bool:
    from repro.checkpoint import manager as CK

    return CK.has_ptq_artifact(directory)


def ptq_matches(directory, cfg: ModelConfig, qcfg: QuantConfig) -> bool:
    """True iff an artifact exists AND was built for exactly this config —
    i.e. a boot from it will actually skip calibration + α-search."""
    from repro.checkpoint import manager as CK

    if not CK.has_ptq_artifact(directory):
        return False
    try:
        meta = json.loads((Path(directory) / "meta.json").read_text())
    except (ValueError, OSError):
        return False  # corrupt/unreadable metadata ≙ no usable artifact
    return meta.get("config_hash") == ptq_fingerprint(cfg, qcfg)


def save_ptq(directory, qparams, report: PTQReport, cfg: ModelConfig,
             qcfg: QuantConfig) -> Path:
    """Persist the quantized pytree + report as a self-describing artifact."""
    from repro.checkpoint import manager as CK

    # A8 flags are static tree *metadata* (not npz payload), so they're
    # snapshotted here from the tree itself — the source of truth — and
    # re-applied by load_ptq (the manager rebuilds with the default a8=True).
    a8_flags = _tree_a8_flags(qparams, report.quantized_paths)
    meta = {
        "config_hash": ptq_fingerprint(cfg, qcfg),
        "model": cfg.name,
        "report": {
            "alpha": float(report.alpha),
            "search_loss": float(report.search_loss),
            "loss_curve": {str(k): float(v)
                           for k, v in report.loss_curve.items()},
            "quantized_paths": [list(map(str, p))
                                for p in report.quantized_paths],
            "fp_bytes": int(report.fp_bytes),
            "quant_bytes": int(report.quant_bytes),
            "a8_eligibility": a8_flags,
            "a8_errors": {k: float(v) for k, v in report.a8_errors.items()},
        },
    }
    return CK.save_ptq_artifact(directory, qparams, meta)


def load_ptq(directory, cfg: ModelConfig,
             qcfg: QuantConfig) -> Tuple[Any, PTQReport]:
    """Boot from a saved artifact: zero calibration, zero α-search.

    Raises :class:`StalePTQArtifactError` when the artifact's config hash does
    not match ``(cfg, qcfg)``."""
    from repro.checkpoint import manager as CK

    tree, meta = CK.load_ptq_artifact(directory)
    want = ptq_fingerprint(cfg, qcfg)
    if meta.get("config_hash") != want:
        raise StalePTQArtifactError(
            f"PTQ artifact at {directory} was built for config hash "
            f"{meta.get('config_hash')!r}, engine wants {want!r} "
            f"(model/quant config changed — re-quantize)")
    r = meta["report"]
    report = PTQReport(
        alpha=r["alpha"], search_loss=r["search_loss"],
        loss_curve={float(k): v for k, v in r["loss_curve"].items()},
        quantized_paths=[tuple(p) for p in r["quantized_paths"]],
        fp_bytes=r["fp_bytes"], quant_bytes=r["quant_bytes"],
        a8_eligibility={k: bool(v)
                        for k, v in r.get("a8_eligibility", {}).items()},
        a8_errors={k: float(v) for k, v in r.get("a8_errors", {}).items()},
    )
    # re-stamp the static a8 flags (the npz holds only array payloads; the
    # manager rebuilds QuantizedTensors with the permissive default a8=True)
    for p in report.quantized_paths:
        flag = report.a8_eligibility.get("/".join(map(str, p)))
        if flag is None:
            continue
        node = SM.tget(tree, p)
        if isinstance(node, QuantizedTensor):
            tree = SM.tset(tree, p, dataclasses.replace(node, a8=flag))
        elif isinstance(node, dict):
            tree = SM.tset(tree, p, {
                k: dataclasses.replace(v, a8=flag) for k, v in node.items()})
    return tree, report
