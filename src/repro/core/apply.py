"""End-to-end SmoothQuant+ PTQ pipeline:  calibrate → search α → smooth →
group-wise int4-quantize.  Mirrors the paper's vLLM flow: the user hands us
FP16/bf16 params; quantization happens during placement (quantize-on-load),
so only packed int4 + scales ever reside in device memory for linear weights.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import calibration as C
from repro.core import search as S
from repro.core import smoothing as SM
from repro.core.quantize import quantize


@dataclasses.dataclass
class PTQReport:
    alpha: float
    search_loss: float
    loss_curve: Dict[float, float]
    quantized_paths: List[Tuple[Any, ...]]
    fp_bytes: int
    quant_bytes: int


def quantizable_paths(cfg: ModelConfig) -> List[Tuple[Any, ...]]:
    """All weights named by the smoothing group table (the PTQ target set)."""
    out: List[Tuple[Any, ...]] = []
    for g in SM.smoothing_groups(cfg):
        out.extend(g.weights)
    return out


def quantize_params(
    params, cfg: ModelConfig, qcfg: QuantConfig
) -> Tuple[Any, List[Tuple[Any, ...]], int, int]:
    """Replace every quantizable linear weight with a QuantizedTensor."""
    fp_bytes = quant_bytes = 0
    done = []
    for wp in quantizable_paths(cfg):
        try:
            w = SM.tget(params, wp)
        except (KeyError, TypeError):
            continue  # block absent in this layout (e.g. no hybrid tail)
        qt = quantize(w, group_size=qcfg.group_size, dtype=cfg.jdtype)
        params = SM.tset(params, wp, qt)
        fp_bytes += w.size * 2
        quant_bytes += qt.nbytes_quant()
        done.append(wp)
    return params, done, fp_bytes, quant_bytes


def smoothquant_plus(
    params,
    cfg: ModelConfig,
    calibration_batches: Iterable[Dict[str, jax.Array]],
    qcfg: QuantConfig = QuantConfig(),
    *,
    step: float = 0.05,
    verbose: bool = False,
) -> Tuple[Any, PTQReport]:
    """The full SmoothQuant+ recipe (paper §3.1.3).

    1. calibrate: channel max |X| per linear input on the calibration set;
    2. grid-search a single global α (step 0.05) minimizing whole-model loss;
    3. smooth (W ← diag(s)W, provider ← provider/s) — mathematically exact;
    4. group-wise 4-bit RTN quantization of the smoothed linear weights.
    """
    col = C.collect_stats(params, cfg, calibration_batches)
    if qcfg.alpha is not None:
        res = S.SearchResult(alpha=qcfg.alpha,
                             loss=S.model_quant_loss(params, cfg, col, qcfg.alpha,
                                                     qcfg.group_size),
                             losses={})
    else:
        res = S.search_alpha(params, cfg, col, step=step,
                             group_size=qcfg.group_size, verbose=verbose)
    smoothed, _ = SM.smooth_model(params, cfg, col, res.alpha)
    if not qcfg.enabled:
        return smoothed, PTQReport(res.alpha, res.loss, res.losses, [], 0, 0)
    qparams, paths, fpb, qb = quantize_params(smoothed, cfg, qcfg)
    return qparams, PTQReport(
        alpha=res.alpha, search_loss=res.loss, loss_curve=res.losses,
        quantized_paths=paths, fp_bytes=fpb, quant_bytes=qb,
    )


def rtn_baseline(params, cfg: ModelConfig, qcfg: QuantConfig = QuantConfig()):
    """Paper baseline: plain group-wise RTN, no smoothing."""
    return quantize_params(params, cfg, qcfg)[0]
