"""Calibration: per-input-channel activation max statistics.

The paper runs the calibration set (HumanEval problem descriptions) through
the FP16 model and records, for every linear layer, ``max|X_j|`` per input
channel j.  We implement this as an *eager, unrolled* forward pass: layer
params are sliced out of the stacked trees one at a time, their leaf ids are
registered with a context collector, and :func:`repro.models.layers
.apply_linear` reports its input when it sees a registered weight.  Weight-
shared blocks (Zamba2's attention) are visited once per call site, so their
stats accumulate the channel-max over *all* call sites automatically.

MoE expert inputs never pass through ``apply_linear`` (they're einsums over
stacked expert weights), so ``apply_moe`` taps the collector explicitly.

Calibration is a one-time offline pass on a handful of sequences; eager
execution is fine (the paper's own calibration is offline too).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# key: (block, layer_idx tuple, weight_subpath) — all tuples of str/int
StatKey = Tuple[Tuple[str, ...], Tuple[int, ...], Tuple[str, ...]]

_COLLECTOR: contextvars.ContextVar = contextvars.ContextVar(
    "smoothquant_collector", default=None
)


@dataclasses.dataclass
class StatsCollector:
    ids: Dict[int, StatKey] = dataclasses.field(default_factory=dict)
    stats: Dict[StatKey, np.ndarray] = dataclasses.field(default_factory=dict)
    # mean |x| accumulators (AWQ uses the mean as importance — §4)
    sums: Dict[StatKey, np.ndarray] = dataclasses.field(default_factory=dict)
    counts: Dict[StatKey, int] = dataclasses.field(default_factory=dict)
    # W4A8 eligibility stat: worst per-token relative RMS error of the int8
    # activation round trip (max over batches).  Meaningful on a
    # *post-smoothing* pass — `smoothquant_plus` runs a second collect over
    # the smoothed model and `apply.derive_a8_eligibility` thresholds this.
    a8_err: Dict[StatKey, float] = dataclasses.field(default_factory=dict)
    moe_key: Optional[Tuple[Tuple[str, ...], Tuple[int, ...]]] = None

    def register_tree(self, block: Tuple[str, ...], lidx: Tuple[int, ...], tree):
        """Register every array leaf of a (sliced, concrete) param tree."""
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            keys = tuple(
                k.key if hasattr(k, "key") else k.idx for k in path
            )
            self.ids[id(leaf)] = (block, lidx, keys)

    def record_input(self, w, x: jax.Array):
        key = self.ids.get(id(w))
        if key is None:
            return
        from repro.core.quantize import a8_roundtrip_error

        ax = tuple(range(x.ndim - 1))
        absx = jnp.abs(x.astype(jnp.float32))
        amax = np.asarray(jnp.max(absx, axis=ax))
        prev = self.stats.get(key)
        self.stats[key] = amax if prev is None else np.maximum(prev, amax)
        asum = np.asarray(jnp.sum(absx, axis=ax))
        n = int(np.prod(x.shape[:-1]))
        self.sums[key] = self.sums.get(key, 0.0) + asum
        self.counts[key] = self.counts.get(key, 0) + n
        err = float(a8_roundtrip_error(x))
        self.a8_err[key] = max(self.a8_err.get(key, 0.0), err)

    def mean_stats(self, key: StatKey) -> np.ndarray:
        return self.sums[key] / max(self.counts.get(key, 1), 1)

    def record_explicit(self, subpath: Tuple[str, ...], amax: jax.Array,
                        a8_err: Optional[jax.Array] = None):
        if self.moe_key is None:
            return
        block, lidx = self.moe_key
        key = (block, lidx, subpath)
        amax = np.asarray(amax, np.float32)
        prev = self.stats.get(key)
        self.stats[key] = amax if prev is None else np.maximum(prev, amax)
        if a8_err is not None:
            self.a8_err[key] = max(self.a8_err.get(key, 0.0), float(a8_err))


def current_collector() -> Optional[StatsCollector]:
    return _COLLECTOR.get()


@contextlib.contextmanager
def collecting(collector: StatsCollector):
    tok = _COLLECTOR.set(collector)
    try:
        yield collector
    finally:
        _COLLECTOR.reset(tok)


def _slice_tree(tree, i: int):
    return jax.tree.map(lambda a: a[i], tree)


def collect_stats(
    params,
    cfg: ModelConfig,
    batches: Iterable[Dict[str, jax.Array]],
) -> StatsCollector:
    """Run calibration batches through the model eagerly, collecting stats."""
    from repro.models import layers as L
    from repro.models import lm as LM
    from repro.models import whisper as W
    from repro.models import mlp as M

    col = StatsCollector()
    with collecting(col):
        for batch in batches:
            if cfg.encdec:
                _whisper_pass(col, params, cfg, batch, W, L, M)
            else:
                _lm_pass(col, params, cfg, batch, LM, L)
    return col


def _lm_pass(col, params, cfg, batch, LM, L):
    tokens = batch["tokens"]
    b, t = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x = LM._embed_in(params, tokens, cfg, batch.get("embeds"))

    def run_block(block_key, lidx, ptree, x, mixer=None):
        col.register_tree(block_key, lidx, ptree)
        col.moe_key = (block_key, lidx)
        x, _ = LM._block_forward(ptree, x, pos, cfg, mixer=mixer, backend="xla")
        col.moe_key = None
        return x

    if cfg.family == "hybrid":
        g, k, tail = LM._hybrid_layout(cfg)
        shared = params["shared"]
        for gi in range(g):
            gtree = _slice_tree(params["groups"], gi)
            for ki in range(k):
                x = run_block(("groups",), (gi, ki), _slice_tree(gtree, ki), x,
                              mixer="mamba2")
            # shared block: SAME key across call sites → stats take channel max
            col.register_tree(("shared",), (), shared)
            col.moe_key = (("shared",), ())
            x, _ = LM._block_forward(
                shared, x, pos, cfg.with_(moe=None), mixer="attention",
                backend="xla",
            )
            col.moe_key = None
        for ti in range(tail):
            x = run_block(("tail",), (ti,), _slice_tree(params["tail"], ti), x,
                          mixer="mamba2")
    else:
        for i in range(cfg.num_layers):
            x = run_block(("layers",), (i,), _slice_tree(params["layers"], i), x)


def _whisper_pass(col, params, cfg, batch, W, L, M):
    from repro.models import attention as A

    frames, tokens = batch["frames"], batch["tokens"]
    b, te, d = frames.shape
    x = frames + W.sinusoid(te, d).astype(frames.dtype)[None]
    epos = jnp.broadcast_to(jnp.arange(te, dtype=jnp.int32)[None], (b, te))
    for i in range(cfg.enc_layers):
        lp = _slice_tree(params["enc"]["layers"], i)
        col.register_tree(("enc",), (i,), lp)
        h = L.apply_norm(lp["norm1"], x)
        y, _ = A.gqa_prefill(lp["self_attn"], h, epos, cfg, backend="xla", causal=False)
        x = x + y
        h = L.apply_norm(lp["norm2"], x)
        x = x + M.apply_mlp(lp["mlp"], h, backend="xla")
    enc_out = L.apply_norm(params["enc"]["final_norm"], x)

    bt, td = tokens.shape
    x = L.apply_embedding(params["dec"]["embed"], tokens)
    x = x + W.sinusoid(td, cfg.d_model).astype(x.dtype)[None]
    dpos = jnp.broadcast_to(jnp.arange(td, dtype=jnp.int32)[None], (bt, td))
    for i in range(cfg.num_layers):
        lp = _slice_tree(params["dec"]["layers"], i)
        col.register_tree(("dec",), (i,), lp)
        x, _ = W._dec_block(lp, x, dpos, enc_out, cfg, backend="xla")


# ---------------------------------------------------------------- dataset ---
def synthetic_calibration_set(
    cfg: ModelConfig,
    *,
    n_seqs: int = 8,
    seq_len: int = 64,
    domain: str = "humaneval",
    seed: int = 0,
) -> List[Dict[str, jax.Array]]:
    """Offline stand-in for the paper's calibration sets.

    Three "domains" reproduce the paper's Table-3 sensitivity axis: each
    domain draws token ids from a differently-shaped Zipf distribution over a
    different vocabulary slice, giving measurably different channel
    statistics (the mechanism behind the paper's Pile/C4/HumanEval contrast).
    """
    zipf_a = {"humaneval": 1.3, "pile": 1.1, "c4": 1.05}[domain]
    offset = {"humaneval": 0, "pile": 1, "c4": 2}[domain]
    rng = np.random.default_rng(seed + offset * 1000)
    out = []
    for _ in range(n_seqs):
        ranks = rng.zipf(zipf_a, size=(1, seq_len)).astype(np.int64)
        toks = (ranks * (offset * 7919 + 31) % cfg.vocab_size).astype(np.int32)
        batch: Dict[str, jax.Array] = {"tokens": jnp.asarray(toks)}
        if cfg.encdec:
            emb_rng = np.random.default_rng(seed + 7)
            batch["frames"] = jnp.asarray(
                emb_rng.standard_normal((1, seq_len, cfg.d_model), np.float32)
            ).astype(cfg.jdtype)
        if cfg.family == "vlm":
            emb_rng = np.random.default_rng(seed + 9)
            batch["embeds"] = jnp.asarray(
                emb_rng.standard_normal((1, 4, cfg.d_model), np.float32)
            ).astype(cfg.jdtype)
        out.append(batch)
    return out
