"""SmoothQuant+ smoothing: per-channel scale transfer with exact fusion.

For every *smoothing group* — a set of linear weights sharing one input
activation — we compute (paper eq. 6)::

    s_j = max|X_j|^alpha / max|W_j|^(1-alpha)

and apply ``W <- diag(s) W`` (rows scaled).  The matching ``X <- X diag(s)^-1``
is *fused into the provider* of that activation so inference sees zero extra
ops (paper §2.2, Fig. 5):

  kind "norm"            divide the preceding (RMS/Layer)Norm scale (and bias)
  kind "linear_out"      divide the preceding linear's output columns
                         (exact when the op between them is per-channel
                         linear: attention·V, SwiGLU's ⊙up, Mamba2's gate)
  kind "linear_out_sqrt" divide by sqrt(s) — for RWKV6's channel-mix where
                         the intermediate is relu(·)² (so col scale c → c²)
  kind "linear_out_mla_v" divide only the V-columns of DeepSeek's wkv_b
  kind "none"            no smoothing possible (e.g. GELU MLP down-proj whose
                         producer is non-linear) — the weight is still
                         quantized, with s = 1

``tie="kv"`` handles GQA's o-proj: its input has H·Dh channels but the fusion
target (wv output) only Hkv·Dh; s is reduced (max) over each KV-head's query
group first, which keeps the transform exact at slightly reduced freedom.

Weight-shared blocks (Zamba2 shared attention) appear once in the group list;
their calibration stats already hold the channel-max over all call sites.

``row_compensations`` lists *non-quantized* consumers of the same activation
(MoE router, RWKV6 decay-LoRA A-matrix): their rows are scaled by ``s`` so the
model stays mathematically equivalent, but they are not quantized.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.calibration import StatsCollector

Path = Tuple[Any, ...]


@dataclasses.dataclass(frozen=True)
class Provider:
    kind: str                       # norm | linear_out | linear_out_sqrt | linear_out_mla_v | none
    path: Path = ()
    extra: Any = None


@dataclasses.dataclass(frozen=True)
class Group:
    name: str
    weights: Tuple[Path, ...]       # quantized + smoothed (path to the ARRAY)
    provider: Provider
    stats_block: Tuple[str, ...]    # collector block key
    stats_sub: Tuple[str, ...]      # collector weight subpath
    row_compensations: Tuple[Path, ...] = ()
    tie: Optional[str] = None       # None | "kv"
    layer_reduce: bool = False      # share s across the stacked layer dim


# ------------------------------------------------------------- tree utils ---
def tget(tree, path: Path):
    for k in path:
        tree = tree[k]
    return tree


def tset(tree, path: Path, val, *, create: bool = False):
    """Functionally set ``path`` to ``val``.  A missing segment raises
    KeyError (a mistyped path must fail loudly, not graft a dead branch)
    unless ``create=True`` — used only where growing the tree is the point
    (quantize_params inserting ``wkv_b_absorbed`` next to ``wkv_b``)."""
    if not path:
        return val
    out = dict(tree)
    if create and isinstance(tree, dict) and path[0] not in tree:
        sub = {}
    else:
        sub = tree[path[0]]
    out[path[0]] = tset(sub, path[1:], val, create=create)
    return out


# ------------------------------------------------------------ group tables --
def _attn_groups(cfg: ModelConfig, blk: Tuple[str, ...], mixer_key="mixer",
                 norm1="norm1") -> List[Group]:
    m = blk + (mixer_key,)
    return [
        Group(
            name="/".join(map(str, blk)) + ".qkv",
            weights=(m + ("wq", "w"), m + ("wk", "w"), m + ("wv", "w")),
            provider=Provider("norm", blk + (norm1,)),
            stats_block=(blk[0],), stats_sub=m[1:] + ("wq", "w"),
        ),
        Group(
            name="/".join(map(str, blk)) + ".wo",
            weights=(m + ("wo", "w"),),
            provider=Provider("linear_out", m + ("wv", "w")),
            stats_block=(blk[0],), stats_sub=m[1:] + ("wo", "w"),
            tie="kv",
        ),
    ]


def _mlp_groups(cfg: ModelConfig, blk: Tuple[str, ...], norm2="norm2") -> List[Group]:
    mlp = blk + ("mlp",)
    gs: List[Group] = []
    if cfg.moe is not None:
        ex = mlp + ("experts",)
        weights = [ex + ("gate",), ex + ("up",)]
        comps = [mlp + ("router", "w")]
        if cfg.moe.num_shared_experts:
            weights += [mlp + ("shared", "gate", "w"), mlp + ("shared", "up", "w")]
        gs.append(Group(
            name="moe.in", weights=tuple(weights),
            provider=Provider("norm", blk + (norm2,)),
            stats_block=(blk[0],), stats_sub=("mlp", "router", "w"),
            row_compensations=tuple(comps),
        ))
        gs.append(Group(
            name="moe.down", weights=(ex + ("down",),),
            provider=Provider("linear_out", ex + ("up",)),
            stats_block=(blk[0],), stats_sub=("mlp", "experts", "down"),
        ))
        if cfg.moe.num_shared_experts:
            gs.append(Group(
                name="moe.shared.down", weights=(mlp + ("shared", "down", "w"),),
                provider=Provider("linear_out", mlp + ("shared", "up", "w")),
                stats_block=(blk[0],), stats_sub=("mlp", "shared", "down", "w"),
            ))
        return gs
    if cfg.mlp == "swiglu":
        gs.append(Group(
            name="mlp.in", weights=(mlp + ("gate", "w"), mlp + ("up", "w")),
            provider=Provider("norm", blk + (norm2,)),
            stats_block=(blk[0],), stats_sub=("mlp", "gate", "w"),
        ))
        gs.append(Group(
            name="mlp.down", weights=(mlp + ("down", "w"),),
            provider=Provider("linear_out", mlp + ("up", "w")),
            stats_block=(blk[0],), stats_sub=("mlp", "down", "w"),
        ))
    else:  # gelu: up smoothable; down has a non-linear producer → s=1
        gs.append(Group(
            name="mlp.in", weights=(mlp + ("up", "w"),),
            provider=Provider("norm", blk + (norm2,)),
            stats_block=(blk[0],), stats_sub=("mlp", "up", "w"),
        ))
        gs.append(Group(
            name="mlp.down", weights=(mlp + ("down", "w"),),
            provider=Provider("none"),
            stats_block=(blk[0],), stats_sub=("mlp", "down", "w"),
        ))
    return gs


def _mla_groups(cfg: ModelConfig, blk: Tuple[str, ...]) -> List[Group]:
    m = blk + ("mixer",)
    mla = cfg.mla
    return [
        Group("mla.a", (m + ("wq_a", "w"), m + ("wkv_a", "w")),
              Provider("norm", blk + ("norm1",)),
              (blk[0],), ("mixer", "wq_a", "w")),
        Group("mla.qb", (m + ("wq_b", "w"),),
              Provider("norm", m + ("norm_q",)),
              (blk[0],), ("mixer", "wq_b", "w")),
        Group("mla.kvb", (m + ("wkv_b", "w"),),
              Provider("norm", m + ("norm_kv",)),
              (blk[0],), ("mixer", "wkv_b", "w")),
        Group("mla.wo", (m + ("wo", "w"),),
              Provider("linear_out_mla_v", m + ("wkv_b", "w"),
                       (cfg.num_heads, mla.qk_nope_head_dim, mla.v_head_dim)),
              (blk[0],), ("mixer", "wo", "w")),
    ]


def _mamba_groups(cfg: ModelConfig, blk: Tuple[str, ...]) -> List[Group]:
    m = blk + ("mixer",)
    return [
        Group("mamba.in",
              (m + ("in_z", "w"), m + ("in_x", "w"), m + ("in_bc", "w"),
               m + ("in_dt", "w")),
              Provider("norm", blk + ("norm1",)),
              (blk[0],), ("mixer", "in_z", "w")),
        Group("mamba.out", (m + ("out_proj", "w"),),
              Provider("norm", m + ("norm",)),
              (blk[0],), ("mixer", "out_proj", "w")),
    ]


def _rwkv_groups(cfg: ModelConfig, blk: Tuple[str, ...]) -> List[Group]:
    m = blk + ("mixer",)
    mlp = blk + ("mlp",)
    return [
        Group("rwkv.in",
              (m + ("wr", "w"), m + ("wk", "w"), m + ("wv", "w"), m + ("wg", "w")),
              Provider("norm", blk + ("norm1",)),
              (blk[0],), ("mixer", "wr", "w"),
              row_compensations=(m + ("w_lora_a",),)),
        Group("rwkv.wo", (m + ("wo", "w"),),
              Provider("norm", m + ("ln_x",)),
              (blk[0],), ("mixer", "wo", "w")),
        Group("rwkv.cm.in", (mlp + ("wk", "w"), mlp + ("wr", "w")),
              Provider("norm", blk + ("norm2",)),
              (blk[0],), ("mlp", "wk", "w")),
        Group("rwkv.cm.v", (mlp + ("wv", "w"),),
              Provider("linear_out_sqrt", mlp + ("wk", "w")),
              (blk[0],), ("mlp", "wv", "w")),
    ]


def smoothing_groups(cfg: ModelConfig) -> List[Group]:
    gs: List[Group] = []
    if cfg.encdec:
        for side, n_attn in (("enc", "self_attn"), ("dec", "self_attn")):
            blk = (side, "layers")
            m = blk + (n_attn,)
            gs.append(Group(
                f"{side}.qkv",
                (m + ("wq", "w"), m + ("wk", "w"), m + ("wv", "w")),
                Provider("norm", blk + ("norm1",)),
                (side,), (n_attn, "wq", "w")))
            gs.append(Group(
                f"{side}.wo", (m + ("wo", "w"),),
                Provider("linear_out", m + ("wv", "w")),
                (side,), (n_attn, "wo", "w"), tie="kv"))
        # decoder cross-attn: q fed by norm2; k/v fed by (shared) enc output
        c = ("dec", "layers", "cross_attn")
        gs.append(Group("dec.xq", (c + ("wq", "w"),),
                        Provider("norm", ("dec", "layers", "norm2")),
                        ("dec",), ("cross_attn", "wq", "w")))
        gs.append(Group("dec.xkv", (c + ("wk", "w"), c + ("wv", "w")),
                        Provider("norm", ("enc", "final_norm")),
                        ("dec",), ("cross_attn", "wk", "w"),
                        layer_reduce=True))
        gs.append(Group("dec.xo", (c + ("wo", "w"),),
                        Provider("linear_out", c + ("wv", "w")),
                        ("dec",), ("cross_attn", "wo", "w"), tie="kv"))
        # MLPs (gelu) — enc norm2, dec norm3
        for side, nrm in (("enc", "norm2"), ("dec", "norm3")):
            mlp = (side, "layers", "mlp")
            gs.append(Group(f"{side}.mlp.in", (mlp + ("up", "w"),),
                            Provider("norm", (side, "layers", nrm)),
                            (side,), ("mlp", "up", "w")))
            gs.append(Group(f"{side}.mlp.down", (mlp + ("down", "w"),),
                            Provider("none"), (side,), ("mlp", "down", "w")))
        return gs

    if cfg.family == "hybrid":
        for blk in (("groups",), ("tail",)):
            gs += _mamba_groups(cfg, blk)
        gs += _attn_groups(cfg, ("shared",))
        gs += _mlp_groups(cfg.with_(moe=None), ("shared",))
        return gs

    blk = ("layers",)
    if cfg.mixer == "attention":
        gs += _attn_groups(cfg, blk)
        gs += _mlp_groups(cfg, blk)
    elif cfg.mixer == "mla":
        gs += _mla_groups(cfg, blk)
        gs += _mlp_groups(cfg, blk)
    elif cfg.mixer == "mamba2":
        gs += _mamba_groups(cfg, blk)
    elif cfg.mixer == "rwkv6":
        gs += _rwkv_groups(cfg, blk)
    return gs


# ----------------------------------------------------------- s computation --
def assemble_stats(col: StatsCollector, block: Tuple[str, ...],
                   sub: Tuple[str, ...]) -> np.ndarray:
    """Gather per-layer stats into a stacked array [*lead, Ci]."""
    entries = {k[1]: v for k, v in col.stats.items()
               if k[0] == block and k[2] == sub}
    if not entries:
        raise KeyError(f"no calibration stats for {block}+{sub}")
    idxs = sorted(entries)
    if idxs == [()]:
        return entries[()]
    depth = len(idxs[0])
    if depth == 1:
        return np.stack([entries[(i,)] for i in range(len(idxs))])
    # depth 2 (hybrid groups): [G, K, ...]
    g = max(i[0] for i in idxs) + 1
    k = max(i[1] for i in idxs) + 1
    return np.stack([
        np.stack([entries[(gi, ki)] for ki in range(k)]) for gi in range(g)
    ])


def _w_absmax_in(w: jax.Array, stat_shape: Tuple[int, ...]) -> np.ndarray:
    """max_j |W[..., i, j]| reduced to ``stat_shape`` (= [*stat_lead, Ci])."""
    a = np.asarray(jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1))
    while a.ndim > len(stat_shape):       # reduce extra middle dims (e.g. E)
        ax = a.ndim - 2                   # innermost lead dim
        a = a.max(axis=ax)
    return a


def compute_group_s(
    params, cfg: ModelConfig, col: StatsCollector, group: Group, alpha: float
) -> np.ndarray:
    """Smoothing factors for one group, shape [*stat_lead, Ci]."""
    act = assemble_stats(col, group.stats_block, group.stats_sub)
    if group.provider.kind == "none":
        return np.ones_like(act)
    if group.layer_reduce:
        # one shared s across the stacked layer dim (the provider is shared,
        # e.g. whisper's enc.final_norm feeding every decoder cross-attn)
        act = np.broadcast_to(act.max(axis=0), act.shape).copy()
    wmax = None
    for wp in group.weights:
        wm = _w_absmax_in(tget(params, wp), act.shape)
        wmax = wm if wmax is None else np.maximum(wmax, wm)
    if group.layer_reduce and wmax is not None:
        wmax = np.broadcast_to(wmax.max(axis=0), wmax.shape).copy()
    eps = 1e-8
    s = np.power(np.maximum(act, eps), alpha) / np.power(
        np.maximum(wmax, eps), 1.0 - alpha
    )
    s = np.where((act > eps) & (wmax > eps), s, 1.0)
    s = np.clip(s, 1e-4, 1e4)
    if group.tie == "kv":
        hkv, grp = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
        dh = s.shape[-1] // (hkv * grp)
        sr = s.reshape(*s.shape[:-1], hkv, grp, dh).max(axis=-2)
        s = np.broadcast_to(
            sr[..., :, None, :], (*s.shape[:-1], hkv, grp, dh)
        ).reshape(s.shape)
    return s.astype(np.float32)


def _align(s: np.ndarray, w: jax.Array) -> jnp.ndarray:
    """Broadcast s [*stat_lead, Ci] against w [*w_lead, Ci, Co] rows."""
    extra = w.ndim - 1 - s.ndim
    shape = (*s.shape[:-1], *([1] * extra), s.shape[-1], 1)
    return jnp.asarray(s.reshape(shape))


def apply_group(params, cfg: ModelConfig, group: Group, s: np.ndarray):
    """Scale group weights by s (rows) and fuse 1/s into the provider."""
    if group.provider.kind == "none":
        return params
    for wp in group.weights + group.row_compensations:
        w = tget(params, wp)
        sal = _align(s, w)
        params = tset(params, wp, (w.astype(jnp.float32) * sal).astype(w.dtype))
    pk, pp = group.provider.kind, group.provider.path
    # a layer_reduce group has one shared s; its provider is a single
    # (unstacked) module, so drop the stacked layer dim before fusing
    s_prov = s[0] if group.layer_reduce else s
    if group.tie == "kv":
        # s is constant over each KV head's query group (built that way);
        # the provider (wv) has only Hkv·Dh output cols — take one per group
        hkv, grp = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
        dh = s_prov.shape[-1] // (hkv * grp)
        s_prov = s_prov.reshape(*s_prov.shape[:-1], hkv, grp, dh)[..., :, 0, :]
        s_prov = s_prov.reshape(*s.shape[:-1], hkv * dh) if not group.layer_reduce \
            else s_prov.reshape(hkv * dh)
    if pk == "norm":
        nrm = tget(params, pp)
        sn = jnp.asarray(s_prov)
        new = dict(nrm, scale=(nrm["scale"].astype(jnp.float32) / sn).astype(nrm["scale"].dtype))
        if "bias" in nrm:
            new["bias"] = (nrm["bias"].astype(jnp.float32) / sn).astype(nrm["bias"].dtype)
        params = tset(params, pp, new)
    elif pk in ("linear_out", "linear_out_sqrt"):
        w = tget(params, pp)
        sd = jnp.asarray(np.sqrt(s_prov) if pk == "linear_out_sqrt" else s_prov)
        extra = w.ndim - 1 - sd.ndim
        cols = sd.reshape(*sd.shape[:-1], *([1] * extra), 1, sd.shape[-1])
        params = tset(params, pp, (w.astype(jnp.float32) / cols).astype(w.dtype))
    elif pk == "linear_out_mla_v":
        h, nope, v = group.provider.extra
        w = tget(params, pp)                        # [*lead, r, H*(nope+v)]
        lead = w.shape[:-2]
        r = w.shape[-2]
        wr = w.astype(jnp.float32).reshape(*lead, r, h, nope + v)
        sv = jnp.asarray(s_prov).reshape(*s_prov.shape[:-1], h, v)  # [*lead, H, v]
        wv_part = wr[..., nope:] / sv[..., None, :, :]
        wr = wr.at[..., nope:].set(wv_part)
        params = tset(params, pp, wr.reshape(w.shape).astype(w.dtype))
    else:
        raise ValueError(pk)
    return params


def smooth_model(
    params, cfg: ModelConfig, col: StatsCollector, alpha: float
) -> Tuple[Any, Dict[str, np.ndarray]]:
    """Apply SmoothQuant+ smoothing at strength alpha.  Returns (params, {group: s})."""
    s_map: Dict[str, np.ndarray] = {}
    for g in smoothing_groups(cfg):
        try:
            s = compute_group_s(params, cfg, col, g, alpha)
        except KeyError:
            continue  # block absent (e.g. no "tail" in this hybrid layout)
        params = apply_group(params, cfg, g, s)
        s_map[g.name] = s
    return params, s_map
