"""AWQ baseline (Lin et al. 2023), as characterised in the paper §4.

Differences from SmoothQuant+ (all three are the paper's criticisms):
- importance factor uses the per-channel activation MEAN (not max);
- alpha is searched PER GROUP (layer-local), minimizing that group's OWN
  weighted quantization loss — error accumulation across layers is ignored;
- the per-layer search is why it's ~5× slower at Code Llama-34B scale (here
  both are fast; we reproduce the accuracy ordering, not the wall time).

Reuses the SmoothQuant+ group/fusion machinery so the comparison isolates
exactly the algorithmic deltas.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import smoothing as SM
from repro.core.calibration import StatsCollector, collect_stats
from repro.core.quantize import fake_quantize
from repro.core.apply import quantize_params


def _awq_s(params, cfg, col, group, alpha, act_mean):
    """AWQ importance: s = mean|X|^alpha / max|W|^(1-alpha), per group."""
    wmax = None
    for wp in group.weights:
        wm = SM._w_absmax_in(SM.tget(params, wp), act_mean.shape)
        wmax = wm if wmax is None else np.maximum(wmax, wm)
    eps = 1e-8
    s = np.power(np.maximum(act_mean, eps), alpha) / np.power(
        np.maximum(wmax, eps), 1.0 - alpha)
    s = np.where((act_mean > eps) & (wmax > eps), s, 1.0)
    s = np.clip(s, 1e-4, 1e4).astype(np.float32)
    if group.layer_reduce:
        s = np.broadcast_to(s.max(axis=0), s.shape).copy()
    if group.tie == "kv":
        hkv, grp = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
        dh = s.shape[-1] // (hkv * grp)
        sr = s.reshape(*s.shape[:-1], hkv, grp, dh).max(axis=-2)
        s = np.broadcast_to(
            sr[..., :, None, :], (*s.shape[:-1], hkv, grp, dh)
        ).reshape(s.shape)
    return s


def _group_loss_at(params, cfg, col, group, alpha, group_size, act_mean):
    s = _awq_s(params, cfg, col, group, alpha, act_mean)
    total = 0.0
    x_hat = jnp.asarray(act_mean / s)
    for wp in group.weights:
        w = SM.tget(params, wp).astype(jnp.float32)
        ws = w * SM._align(s, w)
        err = ws - fake_quantize(ws, group_size)
        extra = w.ndim - 1 - x_hat.ndim
        xb = x_hat.reshape(*x_hat.shape[:-1], *([1] * extra), x_hat.shape[-1], 1)
        total += float(jnp.sum((err * xb) ** 2))
    return total, s


def _assemble_mean(col, block, sub):
    entries = {k[1]: col.mean_stats(k) for k in col.sums
               if k[0] == block and k[2] == sub}
    if not entries:
        # explicit MoE taps record max only; fall back to max stats
        return SM.assemble_stats(col, block, sub)
    idxs = sorted(entries)
    if idxs == [()]:
        return entries[()]
    if len(idxs[0]) == 1:
        return np.stack([entries[(i,)] for i in range(len(idxs))])
    g = max(i[0] for i in idxs) + 1
    k = max(i[1] for i in idxs) + 1
    return np.stack([np.stack([entries[(gi, ki)] for ki in range(k)])
                     for gi in range(g)])


def awq_quantize(
    params,
    cfg: ModelConfig,
    calibration_batches,
    qcfg: QuantConfig = QuantConfig(),
    *,
    step: float = 0.05,
) -> Tuple[object, Dict[str, float]]:
    """Per-group alpha search + smoothing + RTN int4 (AWQ-style)."""
    col = collect_stats(params, cfg, calibration_batches)
    alphas: Dict[str, float] = {}
    grid = np.round(np.arange(0.0, 1.0 + 1e-9, step), 10)
    for g in SM.smoothing_groups(cfg):
        if g.provider.kind == "none":
            continue
        try:
            act = _assemble_mean(col, g.stats_block, g.stats_sub)
        except KeyError:
            continue
        best, best_s = None, None
        for a in grid:
            loss, s = _group_loss_at(params, cfg, col, g, float(a),
                                     qcfg.group_size, act)
            if best is None or loss < best[0]:
                best, best_s = (loss, float(a)), s
        alphas[g.name] = best[1]
        params = SM.apply_group(params, cfg, g, best_s)
    qparams, *_ = quantize_params(params, cfg, qcfg)
    return qparams, alphas
