"""Decoder-only LM assembly for all assigned families.

Layer stacks are ``lax.scan``s over stacked per-layer params (compact HLO,
fast compiles even for 88-layer models).  Three stack styles:

- homogeneous (dense / moe / ssm): one scan over ``num_layers`` blocks;
- hybrid (zamba2): scan over groups of ``attn_every`` mamba blocks followed by
  one application of a *weight-shared* attention+MLP block (per-application
  KV caches), plus an unscanned tail of remainder mamba blocks;
- enc-dec (whisper) lives in ``models/whisper.py``.

Entry points: :func:`init_lm`, :func:`lm_forward` (teacher-forced logits),
:func:`lm_loss`, :func:`lm_prefill`, :func:`lm_decode`, :func:`init_cache`.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mlp as M
from repro.models import ssm as S

Params = Dict[str, Any]


# ------------------------------------------------------------ block defs ----
def _init_mixer(key, cfg: ModelConfig):
    if cfg.mixer == "attention":
        return A.init_gqa(key, cfg)
    if cfg.mixer == "mla":
        return A.init_mla(key, cfg)
    if cfg.mixer == "mamba2":
        return S.init_mamba2(key, cfg)
    if cfg.mixer == "rwkv6":
        return S.init_rwkv6(key, cfg)
    raise ValueError(cfg.mixer)


def _init_block(key, cfg: ModelConfig, *, mixer: Optional[str] = None) -> Params:
    """One decoder block.  ``mixer`` overrides cfg.mixer (hybrid stacks)."""
    mixer = mixer or cfg.mixer
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.jdtype
    p: Params = {"norm1": L.init_norm(cfg.d_model, cfg.norm, dt)}
    sub = cfg.with_(mixer=mixer)
    p["mixer"] = _init_mixer(k1, sub)
    if mixer in ("attention", "mla"):
        p["norm2"] = L.init_norm(cfg.d_model, cfg.norm, dt)
        p["mlp"] = M.init_moe(k2, cfg) if cfg.moe else M.init_mlp(k2, cfg)
    elif mixer == "rwkv6":
        p["norm2"] = L.init_norm(cfg.d_model, cfg.norm, dt)
        p["mlp"] = S.init_rwkv_channel_mix(k3, cfg)
    # mamba2 blocks: mixer only (Zamba2-style), no separate MLP
    return p


def _block_forward(
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    mixer: Optional[str] = None,
    backend: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence (train / prefill-no-cache) block.  Returns (x, aux)."""
    mixer = mixer or cfg.mixer
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(p["norm1"], x)
    if mixer == "attention":
        y, _ = A.gqa_prefill(p["mixer"], h, positions, cfg, backend=backend)
    elif mixer == "mla":
        y, _ = A.mla_prefill(p["mixer"], h, positions, cfg, backend=backend)
    elif mixer == "mamba2":
        y = S.mamba2_forward(p["mixer"], h, cfg, backend=backend)
    elif mixer == "rwkv6":
        y = S.rwkv6_forward(p["mixer"], h, cfg, backend=backend)
    else:
        raise ValueError(mixer)
    x = x + y
    if "mlp" in p:
        h2 = L.apply_norm(p["norm2"], x)
        if mixer == "rwkv6":
            h2_prev = jnp.pad(h2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
            y2 = S.rwkv_channel_mix(p["mlp"], h2, h2_prev, backend=backend)
        elif cfg.moe is not None and mixer in ("attention", "mla"):
            y2, aux = M.apply_moe(p["mlp"], h2, cfg, backend=backend)
        else:
            y2 = M.apply_mlp(p["mlp"], h2, backend=backend, act=cfg.act_kernel)
        x = x + y2
    return x, aux


def _block_prefill_cache(p, x, positions, cfg, *, mixer=None, backend="auto"):
    """Like _block_forward but also returns the mixer cache/state for decode."""
    mixer = mixer or cfg.mixer
    h = L.apply_norm(p["norm1"], x)
    if mixer == "attention":
        y, cache = A.gqa_prefill(p["mixer"], h, positions, cfg, backend=backend)
    elif mixer == "mla":
        y, cache = A.mla_prefill(p["mixer"], h, positions, cfg, backend=backend)
    elif mixer == "mamba2":
        y, cache = S.mamba2_forward(
            p["mixer"], h, cfg, backend=backend, return_state=True
        )
    elif mixer == "rwkv6":
        y, cache = S.rwkv6_forward(
            p["mixer"], h, cfg, backend=backend, return_state=True
        )
    else:
        raise ValueError(mixer)
    x = x + y
    if "mlp" in p:
        h2 = L.apply_norm(p["norm2"], x)
        if mixer == "rwkv6":
            h2_prev = jnp.pad(h2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
            y2 = S.rwkv_channel_mix(p["mlp"], h2, h2_prev, backend=backend)
            cache = dict(cache, ffn_prev=h2[:, -1])
        elif cfg.moe is not None and mixer in ("attention", "mla"):
            y2, _ = M.apply_moe(p["mlp"], h2, cfg, backend=backend)
        else:
            y2 = M.apply_mlp(p["mlp"], h2, backend=backend, act=cfg.act_kernel)
        x = x + y2
    return x, cache


def _block_decode(p, x, positions, cache, cfg, *, mixer=None, backend="auto"):
    mixer = mixer or cfg.mixer
    h = L.apply_norm(p["norm1"], x)
    if mixer == "attention":
        y, cache = A.gqa_decode(p["mixer"], h, positions, cache, cfg, backend=backend)
    elif mixer == "mla":
        y, cache = A.mla_decode(p["mixer"], h, positions, cache, cfg, backend=backend)
    elif mixer == "mamba2":
        y, cache = S.mamba2_decode(p["mixer"], h, cache, cfg, backend=backend)
    elif mixer == "rwkv6":
        ffn_prev = cache.get("ffn_prev")
        y, tcache = S.rwkv6_decode(
            p["mixer"], h, {k: cache[k] for k in ("wkv", "x_prev")}, cfg, backend=backend
        )
        cache = dict(tcache, ffn_prev=ffn_prev)
    else:
        raise ValueError(mixer)
    x = x + y
    if "mlp" in p:
        h2 = L.apply_norm(p["norm2"], x)
        if mixer == "rwkv6":
            y2 = S.rwkv_channel_mix(
                p["mlp"], h2, cache["ffn_prev"][:, None, :], backend=backend
            )
            cache = dict(cache, ffn_prev=h2[:, 0])
        elif cfg.moe is not None:
            y2, _ = M.apply_moe(p["mlp"], h2, cfg, backend=backend)
        else:
            y2 = M.apply_mlp(p["mlp"], h2, backend=backend, act=cfg.act_kernel)
        x = x + y2
    return x, cache


def _block_decode_paged(p, x, rope_pos, write_pos, pool, table_rows, cfg,
                        *, mixer=None, backend="auto"):
    """Attention-mixer block decode against a paged KV pool (see
    ``models/attention.py`` for the page-table convention).  ``mixer``
    overrides ``cfg.mixer`` (the hybrid stack's weight-shared attention)."""
    mixer = mixer or cfg.mixer
    h = L.apply_norm(p["norm1"], x)
    if mixer == "attention":
        y, pool = A.gqa_decode_paged(
            p["mixer"], h, rope_pos, pool, table_rows, write_pos, cfg,
            backend=backend)
    elif mixer == "mla":
        y, pool = A.mla_decode_paged(
            p["mixer"], h, rope_pos, pool, table_rows, write_pos, cfg,
            backend=backend)
    else:
        raise ValueError(f"paged decode needs an attention mixer, got {mixer}")
    x = x + y
    h2 = L.apply_norm(p["norm2"], x)
    if cfg.moe is not None:
        y2, _ = M.apply_moe(p["mlp"], h2, cfg, backend=backend)
    else:
        y2 = M.apply_mlp(p["mlp"], h2, backend=backend, act=cfg.act_kernel)
    return x + y2, pool


def _block_prefill_chunk(p, x, start_len, chunk_len, pool, table_rows, cfg,
                         *, mixer=None, backend="auto"):
    """Attention-mixer block chunked prefill straight against a paged KV pool
    (see ``models/attention.py`` for the chunk contract).  ``mixer`` overrides
    ``cfg.mixer`` (the hybrid stack's weight-shared attention).  Returns
    (x, updated pool)."""
    mixer = mixer or cfg.mixer
    h = L.apply_norm(p["norm1"], x)
    if mixer == "attention":
        y, pool = A.gqa_prefill_chunk(
            p["mixer"], h, pool, table_rows, start_len, chunk_len, cfg,
            backend=backend)
    elif mixer == "mla":
        y, pool = A.mla_prefill_chunk(
            p["mixer"], h, pool, table_rows, start_len, chunk_len, cfg,
            backend=backend)
    else:
        raise ValueError(f"paged prefill needs an attention mixer, got {mixer}")
    x = x + y
    h2 = L.apply_norm(p["norm2"], x)
    if cfg.moe is not None:
        y2, _ = M.apply_moe(p["mlp"], h2, cfg, backend=backend)
    else:
        y2 = M.apply_mlp(p["mlp"], h2, backend=backend, act=cfg.act_kernel)
    return x + y2, pool


def lm_prefill_chunk(
    p: Params,
    tokens: jax.Array,            # [B, T] int32 chunk tokens (right-padded)
    cache: Any,                   # pools from init_paged_cache
    start_len: jax.Array,         # [B] int32 tokens already in the pages
    chunk_len: jax.Array,         # [B] int32 valid rows of this chunk (<= T)
    table_rows: jax.Array,        # [B, P] int32 page table
    cfg: ModelConfig,
    *,
    backend: str = "auto",
    last_idx=None,
    embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Any]:
    """Chunked prefill: process one ``[B, T]`` prompt chunk per slot, KV
    scattered straight into the paged pools, attention reading every earlier
    token (cached prefix pages and prior chunks alike) through the page
    table.  Row ``b``'s chunk token ``t`` sits at logical position
    ``start_len[b] + t``.  Returns per-row logits gathered at ``last_idx``
    (only meaningful on a prompt's final chunk) and the updated pools — the
    pools ride the layer scan as ys, exactly like :func:`lm_decode_paged`.
    """
    b, t = tokens.shape[:2]
    x = _embed_in(p, tokens, cfg, embeds)

    def body(x, inp):
        lp, pool = inp
        x, pool = _block_prefill_chunk(
            lp, x, start_len, chunk_len, pool, table_rows, cfg,
            backend=backend)
        return x, pool

    x, pools = jax.lax.scan(body, x, (p["layers"], cache["layers"]))
    idx = last_idx if last_idx is not None else jnp.full((b,), t - 1, jnp.int32)
    x_last = x[jnp.arange(b), idx][:, None]
    return _lm_head(p, x_last, cfg, backend)[:, 0], {"layers": pools}


# ------------------------------------------------------------- LM wiring ----
def _hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    k = cfg.hybrid.attn_every
    groups = cfg.num_layers // k
    tail = cfg.num_layers - groups * k
    return groups, k, tail


def init_lm(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    dt = cfg.jdtype
    p: Params = {
        "embed": L.init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.init_linear(ks[1], cfg.d_model, cfg.vocab_size, dt)

    def stack(init_fn, n, base_key):
        leaves = [init_fn(jax.random.fold_in(base_key, i)) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    if cfg.family == "hybrid":
        g, k, tail = _hybrid_layout(cfg)
        p["groups"] = stack(
            lambda kk: stack(
                lambda k2: _init_block(k2, cfg, mixer="mamba2"), k, kk
            ),
            g,
            ks[2],
        )
        p["shared"] = _init_block(ks[3], cfg.with_(moe=None), mixer="attention")
        if tail:
            p["tail"] = stack(
                lambda kk: _init_block(kk, cfg, mixer="mamba2"), tail, ks[4]
            )
    else:
        p["layers"] = stack(lambda kk: _init_block(kk, cfg), cfg.num_layers, ks[2])
    return p


def _embed_in(p, tokens, cfg, embeds):
    x = L.apply_embedding(p["embed"], tokens)
    if embeds is not None:
        # modality stub: precomputed frame/patch embeddings added at the
        # (fixed) prefix positions — tokens there are pad (0)
        n = embeds.shape[1]
        x = x.at[:, :n, :].add(embeds.astype(x.dtype))
    return x


def _lm_head(p, x, cfg, backend):
    x = L.apply_norm(p["final_norm"], x)
    if cfg.tie_embeddings:
        return L.logits_from_embedding(p["embed"], x)
    return jnp.dot(
        x, p["lm_head"]["w"].astype(x.dtype), preferred_element_type=jnp.float32
    )


def _default_positions(cfg, b, t, positions):
    if positions is not None:
        return positions
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    return pos


def lm_forward(
    p: Params,
    tokens: jax.Array,            # [B, T] int32
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    backend: str = "auto",
    remat: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced forward.  Returns (logits [B,T,V], moe_aux)."""
    b, t = tokens.shape[:2]
    pos = _default_positions(cfg, b, t, positions)
    x = _embed_in(p, tokens, cfg, embeds)

    if cfg.family == "hybrid":
        shared = p["shared"]

        def mamba_body(carry, lp):
            x = carry
            x, _ = _block_forward(lp, x, pos, cfg, mixer="mamba2", backend=backend)
            return x, None

        mamba_body_ = jax.checkpoint(mamba_body) if remat else mamba_body

        def group_body(carry, gp):
            x = carry
            x, _ = jax.lax.scan(mamba_body_, x, gp)
            x, _ = _block_forward(
                shared, x, pos, cfg.with_(moe=None), mixer="attention",
                backend=backend,
            )
            return x, None

        group_body_ = jax.checkpoint(group_body) if remat else group_body
        x, _ = jax.lax.scan(group_body_, x, p["groups"])
        if "tail" in p:
            x, _ = jax.lax.scan(mamba_body_, x, p["tail"])
        aux = jnp.zeros((), jnp.float32)
    else:
        def body(carry, lp):
            x, aux = carry
            x, a = _block_forward(lp, x, pos, cfg, backend=backend)
            return (x, aux + a), None

        body_ = jax.checkpoint(body) if remat else body
        (x, aux), _ = jax.lax.scan(body_, (x, jnp.zeros((), jnp.float32)), p["layers"])

    return _lm_head(p, x, cfg, backend), aux


def lm_loss(
    p: Params,
    tokens: jax.Array,
    labels: jax.Array,
    cfg: ModelConfig,
    *,
    positions=None,
    embeds=None,
    backend: str = "auto",
    remat: bool = False,
    aux_weight: float = 0.01,
) -> jax.Array:
    logits, aux = lm_forward(
        p, tokens, cfg, positions=positions, embeds=embeds, backend=backend,
        remat=remat,
    )
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # one-hot-reduce (NOT take_along_axis): with vocab-sharded logits this
    # lowers to a local masked reduce + tiny [B,S] psum instead of an
    # all-gather/all-reduce of the full logits tensor under GSPMD
    v = logits.shape[-1]
    onehot = jax.nn.one_hot(labels, v, dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = (logz - gold).mean()
    return nll + aux_weight * aux


# ------------------------------------------------------- prefill / decode ---
def init_cache(cfg: ModelConfig, batch: int, smax: int) -> Any:
    """Decode cache pytree (stacked over layers)."""
    def one_attn():
        return (
            A.init_mla_cache(cfg, batch, smax)
            if cfg.mixer == "mla"
            else A.init_gqa_cache(cfg, batch, smax)
        )

    def one_ssm(mixer):
        if mixer == "mamba2":
            return S.init_mamba2_state(cfg, batch)
        st = S.init_rwkv6_state(cfg, batch)
        st["ffn_prev"] = jnp.zeros((batch, cfg.d_model), cfg.jdtype)
        return st

    def stackn(mk, n):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[mk() for _ in range(n)])

    if cfg.family == "hybrid":
        g, k, tail = _hybrid_layout(cfg)
        return {
            "groups": stackn(lambda: stackn(lambda: one_ssm("mamba2"), k), g),
            "shared": stackn(lambda: A.init_gqa_cache(cfg, batch, smax), g),
            "tail": stackn(lambda: one_ssm("mamba2"), tail) if tail else None,
        }
    if cfg.mixer in ("attention", "mla"):
        return {"layers": stackn(one_attn, cfg.num_layers)}
    return {"layers": stackn(lambda: one_ssm(cfg.mixer), cfg.num_layers)}


def paged_supported(cfg: ModelConfig) -> Tuple[bool, str]:
    """Whether the paged serving engine covers this config.

    Three state-leaf layouts are served: pure KV-page stacks (attention /
    MLA decoders), hybrid stacks (KV pages for the weight-shared attention
    applications + fixed SSM state rows swapped alongside them), and
    enc-dec (KV pages for decoder self-attention + read-only encoder
    pages for cross-attention)."""
    if cfg.encdec:
        return True, ""
    if cfg.family == "hybrid":
        return True, ""
    if cfg.mixer not in ("attention", "mla"):
        return False, f"{cfg.mixer} state is O(1) per slot; paging buys nothing"
    return True, ""


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int) -> Any:
    """Per-layer paged KV pools (stacked over layers, shared across slots).

    Hybrid stacks page only the shared-attention applications (one pool
    layer per group); their SSM state lives in the separate fixed-rows tree
    from :func:`init_fixed_state`.  Enc-dec pools live in
    ``models/whisper.py`` (dispatched by ``models/api.py``)."""
    ok, why = paged_supported(cfg)
    if not ok:
        raise NotImplementedError(why)
    if cfg.encdec:
        raise ValueError("enc-dec paged pools live in models/whisper.py")
    if cfg.family == "hybrid":
        g, _, _ = _hybrid_layout(cfg)
        return {"layers": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[A.init_gqa_page_pool(cfg, num_pages, page_size)
              for _ in range(g)])}
    mk = (
        (lambda: A.init_mla_page_pool(cfg, num_pages, page_size))
        if cfg.mixer == "mla"
        else (lambda: A.init_gqa_page_pool(cfg, num_pages, page_size))
    )
    return {"layers": jax.tree.map(
        lambda *xs: jnp.stack(xs), *[mk() for _ in range(cfg.num_layers)])}


def init_fixed_state(cfg: ModelConfig, batch: int) -> Any:
    """Fixed-rows state leaves for hybrid stacks: per-layer Mamba2 state with
    the slot axis SECOND (``[M, B, ...]``) so the pool-row swap helpers
    (``api.gather_pool_rows`` / ``api.scatter_pool_rows``, axis 1) move a
    slot's rows without reshaping.  Layer order is group-major (the ``g*k``
    grouped mamba layers, then the tail)."""
    if cfg.family != "hybrid":
        raise ValueError(f"fixed-rows state is hybrid-only, got {cfg.family}")
    m = cfg.num_layers
    d_inner, hp, nh, n = S.mamba_dims(cfg)
    km1 = cfg.conv_kernel - 1
    return {
        "h": jnp.zeros((m, batch, nh, hp, n), jnp.float32),
        "conv_x": jnp.zeros((m, batch, km1, d_inner), cfg.jdtype),
        "conv_bc": jnp.zeros((m, batch, km1, 2 * n), cfg.jdtype),
    }


def quantize_raw_paged(raw: Any, cfg: ModelConfig) -> Any:
    """Quantize raw prefill KV (``{"layers": {leaf: [L, n, T, ...]}}``) to
    match the int8 page pools: every KV leaf becomes int8 codes plus a
    ``<leaf>_s`` f32 per-row scale leaf (per (layer, row, position[, head])),
    so the admission scatter (``serving.kv_cache.write_prefix``) maps 1:1
    onto the pool tree.  No-op when ``cfg.kv_quant`` is off."""
    if not cfg.kv_quant:
        return raw
    out = {}
    for name, leaf in raw["layers"].items():
        if name == "lens":
            continue
        codes, scales = A.kv_quantize_rows(leaf)
        out[name] = codes
        out[name + "_s"] = scales.astype(jnp.float32)
    return {"layers": out}


def lm_decode_paged(
    p: Params,
    token: jax.Array,             # [B, 1] int32
    cache: Any,                   # pools from init_paged_cache
    position: jax.Array,          # [B] int32 current position
    table_rows: jax.Array,        # [B, P] int32 page table
    cfg: ModelConfig,
    *,
    backend: str = "auto",
) -> Tuple[jax.Array, Any]:
    """One decode step against paged KV pools.  Returns (logits, new pools)."""
    b = token.shape[0]
    pos = position[:, None]
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(position[None, :, None], (3, b, 1))
    x = L.apply_embedding(p["embed"], token)

    def step(x, inp):
        lp, st = inp
        x, st = _block_decode_paged(
            lp, x, pos, position, st, table_rows, cfg, backend=backend)
        return x, st

    x, nst = jax.lax.scan(step, x, (p["layers"], cache["layers"]))
    logits = _lm_head(p, x, cfg, backend)[:, 0]
    return logits, {"layers": nst}


def _group_fixed(fixed, g, k):
    """Split the [M, B, ...] fixed-state tree into grouped [g, k, B, ...] and
    tail [tail, B, ...] trees (group-major layer order, tail last)."""
    grouped = jax.tree.map(lambda a: a[: g * k].reshape(g, k, *a.shape[1:]), fixed)
    tail_st = jax.tree.map(lambda a: a[g * k:], fixed)
    return grouped, tail_st


def _ungroup_fixed(grouped, tail_st, tail):
    flat = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), grouped)
    if tail:
        flat = jax.tree.map(
            lambda a, t_: jnp.concatenate([a, t_], axis=0), flat, tail_st)
    return flat


def hybrid_decode_paged(
    p: Params,
    token: jax.Array,             # [B, 1] int32
    cache: Any,                   # g shared-attn pools from init_paged_cache
    fixed: Any,                   # [M, B, ...] tree from init_fixed_state
    position: jax.Array,          # [B] int32 current position
    table_rows: jax.Array,        # [B, P] int32 page table
    active: jax.Array,            # [B] bool: rows actually decoding
    cfg: ModelConfig,
    *,
    backend: str = "auto",
) -> Tuple[jax.Array, Any, Any]:
    """One hybrid decode step: mamba layers update their fixed state rows,
    the weight-shared attention block hits one paged pool per group.  Rows
    with ``active=False`` keep their fixed state untouched (the trash-page
    convention masks their KV writes, but an SSM recurrence would otherwise
    corrupt a parked slot's state).  Returns (logits, pools, fixed)."""
    b = token.shape[0]
    pos = position[:, None]
    x = L.apply_embedding(p["embed"], token)
    g, k, tail = _hybrid_layout(cfg)
    grouped, tail_st = _group_fixed(fixed, g, k)
    shared = p["shared"]
    scfg = cfg.with_(moe=None)

    def mamba_step(x, inp):
        lp, st = inp
        x, st = _block_decode(lp, x, pos, st, cfg, mixer="mamba2", backend=backend)
        return x, st

    def group_step(x, inp):
        gp, gst, pool = inp
        x, new_gst = jax.lax.scan(mamba_step, x, (gp, gst))
        x, pool = _block_decode_paged(
            shared, x, pos, position, pool, table_rows, scfg,
            mixer="attention", backend=backend)
        return x, (new_gst, pool)

    x, (ngst, npools) = jax.lax.scan(
        group_step, x, (p["groups"], grouped, cache["layers"]))
    ntail = tail_st
    if tail:
        x, ntail = jax.lax.scan(mamba_step, x, (p["tail"], tail_st))
    new_fixed = _ungroup_fixed(ngst, ntail, tail)
    new_fixed = jax.tree.map(
        lambda new, old: jnp.where(
            active.reshape((1, b) + (1,) * (new.ndim - 2)), new, old),
        new_fixed, fixed)
    logits = _lm_head(p, x, cfg, backend)[:, 0]
    return logits, {"layers": npools}, new_fixed


def hybrid_prefill_chunk(
    p: Params,
    tokens: jax.Array,            # [B, T] int32 chunk tokens (right-padded)
    cache: Any,                   # g shared-attn pools
    fixed: Any,                   # [M, Bslots, ...] full fixed-state tree
    slots: jax.Array,             # [B] int32 slot ids of the bucket rows
    start_len: jax.Array,         # [B] int32 tokens already processed
    chunk_len: jax.Array,         # [B] int32 valid rows of this chunk
    table_rows: jax.Array,        # [B, P] int32 page table
    cfg: ModelConfig,
    *,
    backend: str = "auto",
    last_idx=None,
) -> Tuple[jax.Array, Any, Any]:
    """Chunked hybrid prefill: mamba layers run the chunked SSD with state-in
    (``mamba2_prefill_chunk``), the shared attention block scatters KV into
    its per-group pool.  Fixed rows are gathered at ``slots`` on the way in
    and scattered back on the way out — every bucket row is an actively
    prefilling slot, so the scatter is unconditional.
    Returns (last-chunk-token logits, pools, fixed)."""
    b, t = tokens.shape[:2]
    x = _embed_in(p, tokens, cfg, None)
    g, k, tail = _hybrid_layout(cfg)
    fx = jax.tree.map(lambda a: a[:, slots], fixed)
    grouped, tail_st = _group_fixed(fx, g, k)
    shared = p["shared"]
    scfg = cfg.with_(moe=None)

    def mamba_body(x, inp):
        lp, st = inp
        h = L.apply_norm(lp["norm1"], x)
        y, st = S.mamba2_prefill_chunk(
            lp["mixer"], h, st, chunk_len, cfg, backend=backend)
        return x + y, st

    def group_body(x, inp):
        gp, gst, pool = inp
        x, new_gst = jax.lax.scan(mamba_body, x, (gp, gst))
        x, pool = _block_prefill_chunk(
            shared, x, start_len, chunk_len, pool, table_rows, scfg,
            mixer="attention", backend=backend)
        return x, (new_gst, pool)

    x, (ngst, npools) = jax.lax.scan(
        group_body, x, (p["groups"], grouped, cache["layers"]))
    ntail = tail_st
    if tail:
        x, ntail = jax.lax.scan(mamba_body, x, (p["tail"], tail_st))
    new_fx = _ungroup_fixed(ngst, ntail, tail)
    new_fixed = jax.tree.map(
        lambda a, r: a.at[:, slots].set(r), fixed, new_fx)
    idx = last_idx if last_idx is not None else jnp.full((b,), t - 1, jnp.int32)
    x_last = x[jnp.arange(b), idx][:, None]
    return _lm_head(p, x_last, cfg, backend)[:, 0], {"layers": npools}, new_fixed


def lm_decode(
    p: Params,
    token: jax.Array,             # [B, 1] int32
    cache: Any,
    position: jax.Array,          # [B] int32 current position
    cfg: ModelConfig,
    *,
    backend: str = "auto",
) -> Tuple[jax.Array, Any]:
    """One decode step.  Returns (logits [B,V], new cache)."""
    b = token.shape[0]
    pos = position[:, None]
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(position[None, :, None], (3, b, 1))
    x = L.apply_embedding(p["embed"], token)

    if cfg.family == "hybrid":
        shared = p["shared"]

        def mamba_step(x, inp):
            lp, st = inp
            x, st = _block_decode(lp, x, pos, st, cfg, mixer="mamba2", backend=backend)
            return x, st

        def group_step(x, inp):
            gp, gst, sc = inp
            x, new_gst = jax.lax.scan(mamba_step, x, (gp, gst))
            x, new_sc = _block_decode(
                shared, x, pos, sc, cfg.with_(moe=None), mixer="attention",
                backend=backend,
            )
            return x, (new_gst, new_sc)

        x, (ngst, nsc) = jax.lax.scan(
            group_step, x, (p["groups"], cache["groups"], cache["shared"])
        )
        ntail = cache["tail"]
        if "tail" in p:
            x, ntail = jax.lax.scan(mamba_step, x, (p["tail"], cache["tail"]))
        new_cache = {"groups": ngst, "shared": nsc, "tail": ntail}
    else:
        def step(x, inp):
            lp, st = inp
            x, st = _block_decode(lp, x, pos, st, cfg, backend=backend)
            return x, st

        x, nst = jax.lax.scan(step, x, (p["layers"], cache["layers"]))
        new_cache = {"layers": nst}

    logits = _lm_head(p, x, cfg, backend)[:, 0]
    return logits, new_cache


def lm_prefill(
    p: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    smax: int,
    *,
    positions=None,
    embeds=None,
    backend: str = "auto",
    last_idx=None,
    raw_cache: bool = False,
) -> Tuple[jax.Array, Any]:
    """Process a prompt, building a decode cache padded to ``smax``.

    For attention archs the per-layer KV is computed in the scan and written
    into the preallocated cache; SSM/hybrid archs replay the prompt through
    the recurrent decode path chunk-free (their state is O(1)).
    Returns (last-token logits [B,V], cache).

    ``last_idx[B]``: per-row index of the real last prompt token — logits are
    gathered there instead of at ``[:, -1]``, so right-padded rows of a
    length-bucketed joint prefill sample from the correct position (causal
    masking already keeps padding out of the valid prefix's KV).
    ``raw_cache=True`` skips the ``smax`` slab: the returned attention caches
    are the raw prefix KV ``[L, B, T, ...]``, ready to be scattered into
    paged pools (no per-request slab allocation).
    """
    b, t = tokens.shape[:2]
    if (last_idx is not None or raw_cache) and cfg.family == "hybrid":
        raise NotImplementedError("bucketed/raw prefill not wired for hybrid")
    pos = _default_positions(cfg, b, t, positions)
    cache = None if raw_cache else init_cache(cfg, b, smax)
    x = _embed_in(p, tokens, cfg, embeds)

    def head_at(x):
        if last_idx is None:
            return _lm_head(p, x, cfg, backend)[:, -1]
        x_last = x[jnp.arange(b), last_idx][:, None]       # [B, 1, D]
        return _lm_head(p, x_last, cfg, backend)[:, 0]

    def pad_kv(ct, new):
        """Write freshly-built prefix cache into the smax-padded slab.

        With ``cfg.kv_quant`` the raw prefix rows are quantized per-(position,
        head) first — the same :func:`repro.models.attention.kv_quantize_rows`
        codes + scale rows the paged admission path writes
        (``quantize_raw_paged``), so the contiguous slab and the page pools
        agree bit-for-bit instead of casting f32 straight into int8."""
        upd = dict(ct)
        new = dict(new)
        if cfg.kv_quant and "k_s" in ct:
            for key in ("k", "v"):
                codes, scl = A.kv_quantize_rows(new[key])
                new[key] = codes
                new[key + "_s"] = scl
        for key in ct:
            if key == "lens":
                upd["lens"] = new["lens"]
            elif key in new and ct[key].ndim >= 2:
                upd[key] = jax.lax.dynamic_update_slice(
                    ct[key], new[key].astype(ct[key].dtype),
                    (0,) * ct[key].ndim,
                )
            elif key in new:
                upd[key] = new[key]
        return upd

    if cfg.family == "hybrid":
        shared = p["shared"]

        def mamba_body(x, inp):
            lp, st = inp
            x, new = _block_prefill_cache(lp, x, pos, cfg, mixer="mamba2", backend=backend)
            return x, new

        def group_body(x, inp):
            gp, gst, sc = inp
            x, new_gst = jax.lax.scan(mamba_body, x, (gp, gst))
            x, new_sc = _block_prefill_cache(
                shared, x, pos, cfg.with_(moe=None), mixer="attention",
                backend=backend,
            )
            return x, (new_gst, pad_kv(sc, new_sc))

        x, (ngr, nsh) = jax.lax.scan(
            group_body, x, (p["groups"], cache["groups"], cache["shared"])
        )
        ntail = cache["tail"]
        if "tail" in p:
            x, ntail = jax.lax.scan(mamba_body, x, (p["tail"], cache["tail"]))
        logits = _lm_head(p, x, cfg, backend)[:, -1]
        return logits, {"groups": ngr, "shared": nsh, "tail": ntail}

    if raw_cache:
        def body_raw(x, lp):
            x, new = _block_prefill_cache(lp, x, pos, cfg, backend=backend)
            return x, new

        x, layers_cache = jax.lax.scan(body_raw, x, p["layers"])
        return head_at(x), {"layers": layers_cache}

    def body(x, inp):
        lp, ct = inp
        x, new = _block_prefill_cache(lp, x, pos, cfg, backend=backend)
        if cfg.mixer in ("attention", "mla"):
            new = pad_kv(ct, new)
        return x, new

    x, layers_cache = jax.lax.scan(body, x, (p["layers"], cache["layers"]))
    return head_at(x), {"layers": layers_cache}
