"""Channel mixers: SwiGLU / GELU MLPs and sort-based top-k MoE.

The MoE uses equal-capacity sort-based dispatch (MaxText-style): tokens are
sorted by assigned expert, sliced into an ``[E, C, D]`` buffer (overflow
dropped, a standard capacity-factor trade-off), run through stacked expert
weights with one einsum (EP-shardable on the expert axis), and combined back
with the router weights.  No ``[tokens, E, C]`` one-hot is ever materialized.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quantize import QuantizedTensor
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.sharding import hints as H


# ------------------------------------------------------------- dense MLP ----
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Dict[str, Any]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.jdtype
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "gate": L.init_linear(ks[0], d, f, dt),
            "up": L.init_linear(ks[1], d, f, dt),
            "down": L.init_linear(ks[2], f, d, dt),
        }
    return {  # gelu (whisper/starcoder-style), biases allowed
        "up": L.init_linear(ks[1], d, f, dt, bias=cfg.attn_bias),
        "down": L.init_linear(ks[2], f, d, dt, bias=cfg.attn_bias),
    }


def apply_mlp(p: Dict[str, Any], x: jax.Array, *, backend: str = "auto",
              act: str = "a16") -> jax.Array:
    if "gate" in p:
        h = L.swiglu(
            L.apply_linear(p["gate"], x, backend=backend, act=act),
            L.apply_linear(p["up"], x, backend=backend, act=act),
        )
    else:
        h = L.gelu(L.apply_linear(p["up"], x, backend=backend, act=act))
    return L.apply_linear(p["down"], h, backend=backend, act=act)


# ------------------------------------------------------------------- MoE ----
def init_moe(key, cfg: ModelConfig) -> Dict[str, Any]:
    m = cfg.moe
    d, fe = cfg.d_model, m.d_expert
    dt = cfg.jdtype
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {
        "router": L.init_linear(ks[0], d, m.num_experts, dt),
        # stacked expert weights [E, D, F] / [E, F, D] (swiglu experts)
        "experts": {
            "gate": (jax.random.normal(ks[1], (m.num_experts, d, fe), jnp.float32) * d**-0.5).astype(dt),
            "up": (jax.random.normal(ks[2], (m.num_experts, d, fe), jnp.float32) * d**-0.5).astype(dt),
            "down": (jax.random.normal(ks[3], (m.num_experts, fe, d), jnp.float32) * fe**-0.5).astype(dt),
        },
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=fe * m.num_shared_experts)
    return p


def _expert_matmul(x: jax.Array, w, *, backend: str = "auto",
                   act: str = "a16") -> jax.Array:
    """Per-expert contraction ``x[nblk, E, C, D] @ w[E, D, F] → [nblk, E, C, F]``
    in f32.

    ``w`` is either a stacked fp array or (after PTQ) a stacked int4
    :class:`QuantizedTensor` — the quantized case dispatches through
    ``kernels.ops.w4a16_grouped_matmul`` (experts ride the Pallas grid; the
    XLA backend fuses the dequant into the einsum), so packed int4 + scales
    stay the only resident weight format on the expert path."""
    nblk, e, c, d = x.shape
    if isinstance(w, QuantizedTensor):
        xe = x.astype(jnp.float32).transpose(1, 0, 2, 3).reshape(e, nblk * c, d)
        y = kops.w4a16_grouped_matmul(xe, w, backend=backend, act=act)
        return y.reshape(e, nblk, c, -1).transpose(1, 0, 2, 3)
    return jnp.einsum(
        "becd,edf->becf", x.astype(jnp.float32), w.astype(jnp.float32))


def _dispatch_indices(expert_ids: jax.Array, num_experts: int, capacity: int):
    """Sort-based dispatch bookkeeping.

    expert_ids: [N] int32 (token-slot → expert).  Returns (buf_idx [N],
    keep [N] bool, inv_perm) such that token-slot i goes to flat buffer row
    ``buf_idx[i]`` (= expert*capacity + position) iff keep[i].
    """
    n = expert_ids.shape[0]
    sort_idx = jnp.argsort(expert_ids, stable=True)            # [N]
    sorted_ids = expert_ids[sort_idx]
    # position of each sorted slot within its expert
    counts = jnp.bincount(expert_ids, length=num_experts)      # [E]
    starts = jnp.cumsum(counts) - counts                       # [E]
    pos_in_expert = jnp.arange(n) - starts[sorted_ids]
    keep_sorted = pos_in_expert < capacity
    buf_sorted = sorted_ids * capacity + jnp.minimum(pos_in_expert, capacity - 1)
    # back to original slot order
    inv = jnp.argsort(sort_idx, stable=True)
    return buf_sorted[inv], keep_sorted[inv]


def apply_moe(
    p: Dict[str, Any],
    x: jax.Array,            # [B, T, D]
    cfg: ModelConfig,
    *,
    backend: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss) — aux is the standard load-balancing loss."""
    m = cfg.moe
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)

    router_logits = L.apply_linear(p["router"], xf, backend=backend).astype(
        jnp.float32
    )                                                           # [N, E]
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, m.top_k)              # [N, K]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # hierarchical dispatch: when a mesh is installed, tokens are blocked by
    # the data(-parallel) axis so the argsort/bincount bookkeeping stays
    # LOCAL to each data shard (a global sort would all-reduce u32 masks
    # across shards); each block fills its own capacity slice per expert
    import os
    mesh = H.current_mesh()
    nblk = 1
    if mesh is not None and not os.environ.get("REPRO_NO_HINTS"):
        sizes = dict(mesh.shape)
        nblk = sizes.get("data", 1) * sizes.get("pod", 1)
        if n % nblk != 0:
            nblk = 1
    n_loc = n // nblk
    capacity = max(int(n_loc * m.top_k / m.num_experts * m.capacity_factor),
                   m.top_k)
    flat_e = gate_e.reshape(nblk, n_loc * m.top_k).astype(jnp.int32)
    buf_idx, keep = jax.vmap(
        lambda e: _dispatch_indices(e, m.num_experts, capacity)
    )(flat_e)                                                    # [nblk, n_loc*K]

    # gather-based dispatch: scatter only the tiny int32 slot→token map, then
    # GATHER the wide rows (a direct scatter of [slots, D] lowers to a u32
    # collision-mask all-reduce under GSPMD — ~500 GB/device on deepseek)
    slot_tok = jnp.full((nblk, m.num_experts * capacity), -1, jnp.int32)
    tok_of_slotsrc = jnp.arange(n_loc * m.top_k, dtype=jnp.int32) // m.top_k
    slot_tok = jax.vmap(
        lambda st, i, k: st.at[jnp.where(k, i, st.shape[0])].set(
            tok_of_slotsrc, mode="drop")
    )(slot_tok, buf_idx, keep)
    xblk = xf.reshape(nblk, n_loc, d)
    buf = jax.vmap(lambda xb, st: xb[jnp.maximum(st, 0)])(xblk, slot_tok)
    buf = jnp.where((slot_tok >= 0)[..., None], buf, 0)
    buf = buf.reshape(nblk, m.num_experts, capacity, d)
    buf = H.shard_hint(buf, ("pod", "data"), "model", None, None)

    # expert compute (EP-shardable over stacked weights); after PTQ the
    # stacked [E, Ci, Co] weights are int4 QuantizedTensors and contract
    # through the grouped W4A16 kernel — never dequantized model-side
    act = cfg.act_kernel
    ew = p["experts"]
    gate_h = _expert_matmul(buf, ew["gate"], backend=backend, act=act)
    up_h = _expert_matmul(buf, ew["up"], backend=backend, act=act)
    hidden = jax.nn.silu(gate_h) * up_h
    from repro.core import calibration as _calib
    from repro.core.quantize import a8_roundtrip_error

    col = _calib.current_collector()
    if col is not None:  # per-expert input stats (einsums bypass apply_linear)
        col.record_explicit(
            ("mlp", "experts", "gate"),
            jnp.max(jnp.abs(buf.astype(jnp.float32)), axis=(0, 2)),
            a8_err=a8_roundtrip_error(buf),
        )
        col.record_explicit(
            ("mlp", "experts", "down"), jnp.max(jnp.abs(hidden), axis=(0, 2)),
            a8_err=a8_roundtrip_error(hidden),
        )
    out = _expert_matmul(hidden, ew["down"], backend=backend,
                         act=act).astype(x.dtype)

    # combine (block-local gather, mirroring the dispatch)
    out_flat = out.reshape(nblk, m.num_experts * capacity, d)
    gathered = jax.vmap(lambda o, i: o[i])(out_flat, buf_idx)   # [nblk, n_loc*K, D]
    gathered = jnp.where(keep[..., None], gathered, 0)
    weighted = gathered.astype(jnp.float32) * gate_w.reshape(nblk, -1)[..., None]
    y = weighted.reshape(n, m.top_k, d).sum(1).astype(x.dtype)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], xf, backend=backend, act=act)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(0)                                           # [E]
    ce = jnp.zeros((m.num_experts,)).at[flat_e.reshape(-1)].add(1.0) / max(
        n * m.top_k, 1)
    aux = m.num_experts * jnp.sum(me * ce)
    return y.reshape(b, t, d), aux
