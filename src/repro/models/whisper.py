"""Whisper-style encoder-decoder backbone.

Per the assignment the conv/mel frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings ``[B, T_enc, D]`` directly to the encoder.
Positional encoding is sinusoidal on both sides (the real model uses learned
decoder positions capped at 448; our assigned shapes need up to 256k decoder
positions, so sinusoidal is used throughout — documented deviation).

An assigned shape ``seq_len`` is split evenly: ``T_enc = T_dec = seq_len//2``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mlp as M

Params = Dict[str, Any]


def sinusoid(t: int, d: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * jnp.log(10000.0) / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_xattn_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    dt = cfg.jdtype
    return {
        "norm1": L.init_norm(cfg.d_model, cfg.norm, dt),
        "self_attn": A.init_gqa(ks[0], cfg),
        "norm2": L.init_norm(cfg.d_model, cfg.norm, dt),
        "cross_attn": A.init_gqa(ks[1], cfg),
        "norm3": L.init_norm(cfg.d_model, cfg.norm, dt),
        "mlp": M.init_mlp(ks[2], cfg),
    }


def init_whisper(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    dt = cfg.jdtype

    def stack(init_fn, n, base):
        leaves = [init_fn(jax.random.fold_in(base, i)) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": L.init_norm(cfg.d_model, cfg.norm, dt),
            "self_attn": A.init_gqa(k1, cfg),
            "norm2": L.init_norm(cfg.d_model, cfg.norm, dt),
            "mlp": M.init_mlp(k2, cfg),
        }

    return {
        "enc": {
            "layers": stack(enc_block, cfg.enc_layers, ks[0]),
            "final_norm": L.init_norm(cfg.d_model, cfg.norm, dt),
        },
        "dec": {
            "embed": L.init_embedding(ks[1], cfg.vocab_size, cfg.d_model, dt),
            "layers": stack(lambda k: _init_xattn_block(k, cfg), cfg.num_layers, ks[2]),
            "final_norm": L.init_norm(cfg.d_model, cfg.norm, dt),
        },
    }


def encode(p: Params, frames: jax.Array, cfg: ModelConfig, *, backend="auto",
           remat: bool = False) -> jax.Array:
    b, t, d = frames.shape
    x = frames + sinusoid(t, d).astype(frames.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(x, lp):
        h = L.apply_norm(lp["norm1"], x)
        y, _ = A.gqa_prefill(lp["self_attn"], h, pos, cfg, backend=backend, causal=False)
        x = x + y
        h = L.apply_norm(lp["norm2"], x)
        x = x + M.apply_mlp(lp["mlp"], h, backend=backend)
        return x, None

    body_ = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_, x, p["enc"]["layers"])
    return L.apply_norm(p["enc"]["final_norm"], x)


def _cross_attend(lp, x, enc_out, cfg, *, backend="auto"):
    """Non-causal cross attention (q from decoder, k/v from encoder)."""
    b, t, _ = x.shape
    s = enc_out.shape[1]
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hdim
    q = L.apply_linear(lp["wq"], x, backend=backend).reshape(b, t, h, dh)
    k = L.apply_linear(lp["wk"], enc_out, backend=backend).reshape(b, s, hkv, dh)
    v = L.apply_linear(lp["wv"], enc_out, backend=backend).reshape(b, s, hkv, dh)
    qp = jnp.zeros((b, t), jnp.int32)
    kp = jnp.zeros((b, s), jnp.int32)
    out = A.chunked_attention(q, k, v, qp, kp, causal=False)
    return L.apply_linear(lp["wo"], out.reshape(b, t, -1), backend=backend)


def _dec_block(lp, x, pos, enc_out, cfg, *, backend="auto"):
    h = L.apply_norm(lp["norm1"], x)
    y, kv = A.gqa_prefill(lp["self_attn"], h, pos, cfg, backend=backend)
    x = x + y
    h = L.apply_norm(lp["norm2"], x)
    x = x + _cross_attend(lp["cross_attn"], h, enc_out, cfg, backend=backend)
    h = L.apply_norm(lp["norm3"], x)
    x = x + M.apply_mlp(lp["mlp"], h, backend=backend)
    return x, kv


def whisper_forward(
    p: Params,
    frames: jax.Array,           # [B, T_enc, D] stub embeddings
    tokens: jax.Array,           # [B, T_dec]
    cfg: ModelConfig,
    *,
    backend: str = "auto",
    remat: bool = False,
) -> jax.Array:
    """Teacher-forced logits [B, T_dec, V]."""
    enc_out = encode(p, frames, cfg, backend=backend, remat=remat)
    b, t = tokens.shape
    x = L.apply_embedding(p["dec"]["embed"], tokens)
    x = x + sinusoid(t, cfg.d_model).astype(x.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(x, lp):
        x, _ = _dec_block(lp, x, pos, enc_out, cfg, backend=backend)
        return x, None

    body_ = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_, x, p["dec"]["layers"])
    x = L.apply_norm(p["dec"]["final_norm"], x)
    return L.logits_from_embedding(p["dec"]["embed"], x)


def whisper_loss(p, frames, tokens, labels, cfg, *, backend="auto", remat=False):
    logits = whisper_forward(p, frames, tokens, cfg, backend=backend,
                             remat=remat).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return (logz - gold).mean()


# --------------------------------------------------------- decode w/cache ---
def init_whisper_cache(cfg: ModelConfig, batch: int, smax: int, enc_len: int):
    def one():
        return {
            "self": A.init_gqa_cache(cfg, batch, smax),
            # cross K/V computed once at prefill
            "xk": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.hdim), cfg.jdtype),
            "xv": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.hdim), cfg.jdtype),
        }

    caches = [one() for _ in range(cfg.num_layers)]
    return {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *caches)}


def whisper_prefill(
    p, frames, tokens, cfg: ModelConfig, smax: int, *, backend="auto"
) -> Tuple[jax.Array, Any]:
    enc_out = encode(p, frames, cfg, backend=backend)
    b, t = tokens.shape
    enc_len = enc_out.shape[1]
    cache = init_whisper_cache(cfg, b, smax, enc_len)
    x = L.apply_embedding(p["dec"]["embed"], tokens)
    x = x + sinusoid(t, cfg.d_model).astype(x.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    h_, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hdim

    def body(x, inp):
        lp, ct = inp
        x, kv = _dec_block(lp, x, pos, enc_out, cfg, backend=backend)
        xk = L.apply_linear(lp["cross_attn"]["wk"], enc_out, backend=backend)
        xv = L.apply_linear(lp["cross_attn"]["wv"], enc_out, backend=backend)
        new = {
            "self": {
                "k": jax.lax.dynamic_update_slice(ct["self"]["k"], kv["k"], (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(ct["self"]["v"], kv["v"], (0, 0, 0, 0)),
                "lens": kv["lens"],
            },
            "xk": xk.reshape(b, enc_len, hkv, dh),
            "xv": xv.reshape(b, enc_len, hkv, dh),
        }
        return x, new

    x, layers = jax.lax.scan(body, x, (p["dec"]["layers"], cache["layers"]))
    x = L.apply_norm(p["dec"]["final_norm"], x)
    logits = L.logits_from_embedding(p["dec"]["embed"], x)[:, -1]
    return logits, {"layers": layers}


def whisper_decode(
    p, token, cache, position, cfg: ModelConfig, *, backend="auto"
) -> Tuple[jax.Array, Any]:
    b = token.shape[0]
    x = L.apply_embedding(p["dec"]["embed"], token)
    x = x + sinusoid(1, cfg.d_model, offset=0).astype(x.dtype)[None]  # pos via rope-free add
    pos = position[:, None]
    h_, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hdim
    grp = h_ // hkv

    def body(x, inp):
        lp, ct = inp
        h = L.apply_norm(lp["norm1"], x)
        y, self_c = A.gqa_decode(lp["self_attn"], h, pos, ct["self"], cfg, backend=backend)
        x = x + y
        # cross attention against cached enc K/V
        h = L.apply_norm(lp["norm2"], x)
        q = L.apply_linear(lp["cross_attn"]["wq"], h, backend=backend).reshape(
            b, hkv, grp, dh
        )
        sc = jnp.einsum(
            "bhgd,bshd->bhgs", q.astype(jnp.float32), ct["xk"].astype(jnp.float32)
        ) * dh**-0.5
        attn = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bhgs,bshd->bhgd", attn, ct["xv"].astype(jnp.float32))
        o = L.apply_linear(
            lp["cross_attn"]["wo"], o.reshape(b, 1, h_ * dh).astype(x.dtype),
            backend=backend,
        )
        x = x + o
        h = L.apply_norm(lp["norm3"], x)
        x = x + M.apply_mlp(lp["mlp"], h, backend=backend)
        return x, dict(ct, self=self_c)

    x, layers = jax.lax.scan(body, x, (p["dec"]["layers"], cache["layers"]))
    x = L.apply_norm(p["dec"]["final_norm"], x)
    logits = L.logits_from_embedding(p["dec"]["embed"], x)[:, 0]
    return logits, {"layers": layers}


# ------------------------------------------------------------ paged serve ---
def sinusoid_at(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embeddings at arbitrary per-row positions ``[B, T] ->
    [B, T, d]`` (same formula as :func:`sinusoid`, vectorized for chunked
    prefill where each slot sits at a different ``start_len``)."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    inv = jnp.exp(-dim * jnp.log(10000.0) / d)
    ang = pos * inv[None, None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_whisper_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int):
    """Paged decode cache: per-decoder-layer self-attention KV pools plus a
    read-only encoder page pool holding each request's cross-attention K/V
    (computed once at admission, shared across requests via the exact-match
    encoder cache).  Encoder pages stay at model dtype — they are written
    once and never rescattered, so ``kv_quant`` applies only to the
    self-attention pools.  Page 0 of every pool is the trash page; zero
    rows are softmax-safe because decode masks them via ``enc_len``."""
    pools = [A.init_gqa_page_pool(cfg, num_pages, page_size)
             for _ in range(cfg.num_layers)]
    hkv, dh = cfg.num_kv_heads, cfg.hdim
    eshp = (cfg.num_layers, num_pages, page_size, hkv, dh)
    return {
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *pools),
        "enc": {
            "xk": jnp.zeros(eshp, cfg.jdtype),
            "xv": jnp.zeros(eshp, cfg.jdtype),
        },
    }


def whisper_enc_kv(p, frames: jax.Array, cfg: ModelConfig, *, backend="auto"):
    """Run the encoder once and project per-decoder-layer cross K/V.

    Returns ``{"xk"/"xv": [L_dec, B, T_enc, Hkv, Dh]}`` — the rows the
    engine scatters into the encoder page pool at admission."""
    enc_out = encode(p, frames, cfg, backend=backend)
    b, s, _ = enc_out.shape
    hkv, dh = cfg.num_kv_heads, cfg.hdim

    def body(carry, lp):
        xk = L.apply_linear(lp["cross_attn"]["wk"], enc_out, backend=backend)
        xv = L.apply_linear(lp["cross_attn"]["wv"], enc_out, backend=backend)
        return carry, (xk.reshape(b, s, hkv, dh), xv.reshape(b, s, hkv, dh))

    _, (xk, xv) = jax.lax.scan(body, 0, p["dec"]["layers"])
    return {"xk": xk, "xv": xv}


def _gather_enc(exk, exv, enc_table, enc_len):
    """Gather a slot's encoder rows from the page pool back into logical
    order.  ``enc_len`` is clamped to >= 1 so rows whose slots hold no
    encoder pages (trash table) still see one valid (zero) row — masked
    softmax stays finite."""
    b, pe = enc_table.shape
    ps = exk.shape[1]
    s = pe * ps
    hkv, dh = exk.shape[-2], exk.shape[-1]
    xk = exk[enc_table].reshape(b, s, hkv, dh)
    xv = exv[enc_table].reshape(b, s, hkv, dh)
    valid = jnp.arange(s, dtype=jnp.int32)[None, :] < jnp.maximum(enc_len, 1)[:, None]
    return xk, xv, valid


def whisper_decode_paged(
    p,
    token: jax.Array,             # [B, 1] int32
    cache,                        # pools from init_whisper_paged_cache
    position: jax.Array,          # [B] int32 decoder position
    table_rows: jax.Array,        # [B, P] int32 self-attn page table
    enc_table: jax.Array,         # [B, Pe] int32 encoder page table
    enc_len: jax.Array,           # [B] int32 valid encoder rows
    cfg: ModelConfig,
    *,
    backend: str = "auto",
):
    """One decode step against paged self-attn pools + read-only encoder
    pages.  Replicates the contiguous :func:`whisper_decode` numerics,
    including its position-0 sinusoid quirk on the decode embedding.
    Returns (logits, new pools) — the enc pool rides through untouched."""
    b = token.shape[0]
    x = L.apply_embedding(p["dec"]["embed"], token)
    x = x + sinusoid(1, cfg.d_model, offset=0).astype(x.dtype)[None]
    pos = position[:, None]
    h_, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hdim
    grp = h_ // hkv

    def body(x, inp):
        lp, pool, exk, exv = inp
        h = L.apply_norm(lp["norm1"], x)
        y, pool = A.gqa_decode_paged(
            lp["self_attn"], h, pos, pool, table_rows, position, cfg,
            backend=backend)
        x = x + y
        h = L.apply_norm(lp["norm2"], x)
        xk, xv, valid = _gather_enc(exk, exv, enc_table, enc_len)
        q = L.apply_linear(lp["cross_attn"]["wq"], h, backend=backend).reshape(
            b, hkv, grp, dh)
        sc = jnp.einsum(
            "bhgd,bshd->bhgs", q.astype(jnp.float32), xk.astype(jnp.float32)
        ) * dh**-0.5
        sc = jnp.where(valid[:, None, None, :], sc, -1e30)
        attn = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bhgs,bshd->bhgd", attn, xv.astype(jnp.float32))
        o = L.apply_linear(
            lp["cross_attn"]["wo"], o.reshape(b, 1, h_ * dh).astype(x.dtype),
            backend=backend,
        )
        x = x + o
        h = L.apply_norm(lp["norm3"], x)
        x = x + M.apply_mlp(lp["mlp"], h, backend=backend)
        return x, pool

    x, npools = jax.lax.scan(
        body, x,
        (p["dec"]["layers"], cache["layers"],
         cache["enc"]["xk"], cache["enc"]["xv"]))
    x = L.apply_norm(p["dec"]["final_norm"], x)
    logits = L.logits_from_embedding(p["dec"]["embed"], x)[:, 0]
    return logits, {"layers": npools, "enc": cache["enc"]}


def whisper_prefill_chunk(
    p,
    tokens: jax.Array,            # [B, T] int32 chunk tokens (right-padded)
    cache,                        # pools from init_whisper_paged_cache
    start_len: jax.Array,         # [B] int32 tokens already in the pages
    chunk_len: jax.Array,         # [B] int32 valid rows of this chunk
    table_rows: jax.Array,        # [B, P] int32 self-attn page table
    enc_table: jax.Array,         # [B, Pe] int32 encoder page table
    enc_len: jax.Array,           # [B] int32 valid encoder rows
    cfg: ModelConfig,
    *,
    backend: str = "auto",
    last_idx=None,
):
    """Chunked decoder prefill against the paged pools: self-attn KV is
    scattered into the slot's pages (same contract as
    :func:`repro.models.attention.gqa_prefill_chunk`), cross attention
    reads the slot's read-only encoder pages.  Prompt tokens use true
    sinusoidal positions ``start_len + t`` (matching
    :func:`whisper_prefill`); the decode-side position-0 quirk only
    applies to generated tokens.  Returns (last-chunk-token logits,
    pools)."""
    b, t = tokens.shape
    positions = start_len[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    x = L.apply_embedding(p["dec"]["embed"], tokens)
    x = x + sinusoid_at(positions, cfg.d_model).astype(x.dtype)
    h_, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hdim

    def body(x, inp):
        lp, pool, exk, exv = inp
        h = L.apply_norm(lp["norm1"], x)
        y, pool = A.gqa_prefill_chunk(
            lp["self_attn"], h, pool, table_rows, start_len, chunk_len, cfg,
            backend=backend)
        x = x + y
        h = L.apply_norm(lp["norm2"], x)
        xk, xv, valid = _gather_enc(exk, exv, enc_table, enc_len)
        q = L.apply_linear(lp["cross_attn"]["wq"], h, backend=backend).reshape(
            b, t, h_, dh)
        qp = jnp.zeros((b, t), jnp.int32)
        kp = jnp.zeros((b, xk.shape[1]), jnp.int32)
        o = A.chunked_attention(q, xk, xv, qp, kp, valid, causal=False)
        x = x + L.apply_linear(
            lp["cross_attn"]["wo"], o.reshape(b, t, -1), backend=backend)
        h = L.apply_norm(lp["norm3"], x)
        x = x + M.apply_mlp(lp["mlp"], h, backend=backend)
        return x, pool

    x, npools = jax.lax.scan(
        body, x,
        (p["dec"]["layers"], cache["layers"],
         cache["enc"]["xk"], cache["enc"]["xv"]))
    x = L.apply_norm(p["dec"]["final_norm"], x)
    idx = last_idx if last_idx is not None else jnp.full((b,), t - 1, jnp.int32)
    x_last = x[jnp.arange(b), idx][:, None]
    logits = L.logits_from_embedding(p["dec"]["embed"], x_last)[:, 0]
    return logits, {"layers": npools, "enc": cache["enc"]}
