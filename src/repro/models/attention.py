"""Attention mixers: GQA (with chunked online-softmax "flash" prefill) and
DeepSeek-V2 MLA (with compressed-latent decode, the memory-saving absorbed
form).

Cache convention (decode): ``{"k": [B,S,Hkv,Dh], "v": [B,S,Hkv,Dh],
"lens": [B] int32}`` — ``lens[b]`` is the number of valid cache entries.
MLA caches the latent instead: ``{"ckv": [B,S,r], "kpe": [B,S,dr], "lens"}``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.hints import shard_hint

NEG_INF = -1e30


# =========================================================== GQA attention ==
def init_gqa(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hdim
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    return {
        "wq": L.init_linear(ks[0], d, h * dh, dt, bias=cfg.attn_bias),
        "wk": L.init_linear(ks[1], d, hkv * dh, dt, bias=cfg.attn_bias),
        "wv": L.init_linear(ks[2], d, hkv * dh, dt, bias=cfg.attn_bias),
        "wo": L.init_linear(ks[3], h * dh, d, dt, bias=cfg.attn_bias),
    }


def _qkv(p, x, positions, cfg: ModelConfig, backend: str):
    b, t, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hdim
    q = L.apply_linear(p["wq"], x, backend=backend, act=cfg.act_kernel).reshape(b, t, h, dh)
    k = L.apply_linear(p["wk"], x, backend=backend, act=cfg.act_kernel).reshape(b, t, hkv, dh)
    v = L.apply_linear(p["wv"], x, backend=backend, act=cfg.act_kernel).reshape(b, t, hkv, dh)
    q = L.apply_rope(q, positions, theta=cfg.rope_theta, variant=cfg.rope)
    k = L.apply_rope(k, positions, theta=cfg.rope_theta, variant=cfg.rope)
    # anchor layouts: batch on data, heads on model (dropped if indivisible)
    dp = ("pod", "data")
    q = shard_hint(q, dp, None, "model", None)
    k = shard_hint(k, dp, None, "model", None)
    v = shard_hint(v, dp, None, "model", None)
    return q, k, v


def chunked_attention(
    q: jax.Array,          # [B, T, H, Dh]
    k: jax.Array,          # [B, S, Hkv, Dh]
    v: jax.Array,          # [B, S, Hkv, Dh]
    q_pos: jax.Array,      # [B, T]
    k_pos: jax.Array,      # [B, S]
    k_valid: Optional[jax.Array] = None,  # [B, S] bool
    *,
    causal: bool = True,
    q_chunk: int = 2048,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention, O(q_chunk·kv_chunk) score blocks in memory."""
    b, t, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                 # may differ from dh (MLA)
    grp = h // hkv
    scale = dh ** -0.5

    q_chunk = min(q_chunk, t)
    kv_chunk = min(kv_chunk, s)
    # pad to multiples
    tp = -(-t // q_chunk) * q_chunk
    sp = -(-s // kv_chunk) * kv_chunk
    if k_valid is None:
        k_valid = jnp.ones((b, s), bool)
    if tp != t:
        q = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, tp - t)))
    if sp != s:
        k = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, sp - s)))
        k_valid = jnp.pad(k_valid, ((0, 0), (0, sp - s)))

    nq, nk = tp // q_chunk, sp // kv_chunk
    # [B, nq, qc, Hkv, grp, Dh] view of q
    qb = q.reshape(b, nq, q_chunk, hkv, grp, dh)
    qpb = q_pos.reshape(b, nq, q_chunk)
    kb = k.reshape(b, nk, kv_chunk, hkv, dh)
    vb = v.reshape(b, nk, kv_chunk, hkv, dv)
    kpb = k_pos.reshape(b, nk, kv_chunk)
    kvb = k_valid.reshape(b, nk, kv_chunk)

    def q_block(carry, qi):
        del carry
        qq = qb[:, qi]            # [B,qc,Hkv,grp,Dh]
        qp = qpb[:, qi]           # [B,qc]

        def kv_block(state, ki):
            m, l, acc = state
            kk = kb[:, ki]        # [B,kc,Hkv,Dh]
            vv = vb[:, ki]
            kp = kpb[:, ki]       # [B,kc]
            kval = kvb[:, ki]
            # scores [B,Hkv,grp,qc,kc]
            sc = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qq.astype(jnp.float32), kk.astype(jnp.float32)
            ) * scale
            mask = kval[:, None, None, None, :]
            if causal:
                mask = mask & (
                    kp[:, None, None, None, :] <= qp[:, None, None, :, None]
                )
            sc = jnp.where(mask, sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vv.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, grp, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, grp, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, grp, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)  # [B,Hkv,grp,qc,Dh]
        return None, out.transpose(0, 3, 1, 2, 4)     # [B,qc,Hkv,grp,Dh]

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(nq))
    # blocks: [nq, B, qc, Hkv, grp, Dv]
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, tp, h, dv)
    return out[:, :t].astype(q.dtype)


def _full_attention(q, k, v, positions, cfg: ModelConfig, causal: bool):
    """Dispatch full-sequence attention: Pallas flash (TPU / interpret) or
    the jnp chunked online-softmax path (CPU, dry-run lowering)."""
    if cfg.attn_impl in ("flash", "flash_interpret"):
        from repro.kernels.flash_attention import flash_attention

        return flash_attention(
            q, k, v, causal=causal,
            interpret=(cfg.attn_impl == "flash_interpret"),
        )
    return chunked_attention(q, k, v, positions, positions, causal=causal)


def gqa_prefill(
    p, x, positions, cfg: ModelConfig, *, backend: str = "auto", causal: bool = True
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, t, _ = x.shape
    q, k, v = _qkv(p, x, positions, cfg, backend)
    out = _full_attention(q, k, v, positions, cfg, causal)
    y = L.apply_linear(p["wo"], out.reshape(b, t, -1), backend=backend, act=cfg.act_kernel)
    return y, {"k": k, "v": v, "lens": jnp.full((b,), t, jnp.int32)}


def _dequant_pages(rows: jax.Array, scales: Optional[jax.Array]) -> jax.Array:
    """Dequantize gathered int8 page rows in-flight (``scales`` broadcast over
    the trailing feature dim); identity when the pool is fp."""
    if scales is None:
        return rows
    return rows.astype(jnp.float32) * scales.astype(jnp.float32)[..., None]


def _chunk_positions(start_len: jax.Array, t: int) -> jax.Array:
    """True logical positions of a ``[B, T]`` chunk whose row ``b`` starts at
    ``start_len[b]`` tokens already written."""
    return start_len[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]


def _scatter_chunk(pool: Dict[str, jax.Array], updates: Dict[str, jax.Array],
                   table_rows: jax.Array, start_len: jax.Array,
                   chunk_len: jax.Array, kv_quant: bool):
    """Scatter a ``[B, T, ...]`` chunk of raw KV rows into the paged pools at
    logical positions ``start_len[b] + t`` (quantizing per row under
    ``kv_quant``).  Padded rows (``t >= chunk_len[b]``) land on the trash
    page, exactly like ``prefix_write_plan`` routes invalid rows."""
    b, t = next(iter(updates.values())).shape[:2]
    ps = pool[next(iter(updates))].shape[1]
    n_pages = table_rows.shape[1]
    pos = _chunk_positions(start_len, t)
    valid = jnp.arange(t, dtype=jnp.int32)[None, :] < chunk_len[:, None]
    lpage = jnp.minimum(pos // ps, n_pages - 1)
    pg = jnp.where(valid, table_rows[jnp.arange(b)[:, None], lpage], 0)
    off = pos % ps
    new_pool = dict(pool)
    for name, rows in updates.items():
        if kv_quant:
            codes, scl = kv_quantize_rows(rows)
            new_pool[name] = pool[name].at[pg, off].set(codes)
            new_pool[name + "_s"] = pool[name + "_s"].at[pg, off].set(
                scl.astype(pool[name + "_s"].dtype))
        else:
            new_pool[name] = pool[name].at[pg, off].set(
                rows.astype(pool[name].dtype))
    return new_pool


def gqa_prefill_chunk(
    p, x, pool: Dict[str, jax.Array], table_rows: jax.Array,
    start_len: jax.Array, chunk_len: jax.Array, cfg: ModelConfig, *,
    backend: str = "auto"
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked prefill straight against the paged pools.

    ``x[B, T, D]`` holds one prompt chunk per slot; row ``b``'s token ``t``
    sits at logical position ``start_len[b] + t`` (rope applied there), where
    ``start_len`` counts every token already in the pages — cached prefix
    pages and earlier chunks alike.  The chunk's own KV is scattered into the
    slot's pages first (quantized under ``kv_quant``); attention then reads
    the ``start_len`` prefix rows *from the pools* — through the Pallas
    chunked-prefill grid on the kernel impls, or the dense ``gather_pages``
    oracle under ``paged_attn_impl="gather"`` — while the chunk attends its
    own suffix K/V raw (pre-quantization), keeping slab-prefill numerics.
    Rows with ``t >= chunk_len[b]`` are padding: scattered to trash, masked
    out as keys.
    """
    b, t, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hdim
    grp = h // hkv
    positions = _chunk_positions(start_len, t)
    q, k, v = _qkv(p, x, positions, cfg, backend)
    new_pool = _scatter_chunk(pool, {"k": k, "v": v}, table_rows, start_len,
                              chunk_len, cfg.kv_quant)
    scale = dh ** -0.5
    impl = _resolve_paged_impl(cfg, backend)

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as K

        out = K.gqa_paged_prefill(
            q.reshape(b, t, hkv, grp, dh), k, v,
            new_pool["k"], new_pool["v"], table_rows, start_len, chunk_len,
            new_pool.get("k_s"), new_pool.get("v_s"), sm_scale=scale,
            backend="interpret" if impl == "pallas_interpret" else "pallas",
        ).reshape(b, t, h, -1)
    else:
        # XLA oracle: dense gather of the prefix pages (the copy the kernel
        # exists to kill), suffix raw — identical masks to the kernel grid
        pk = _dequant_pages(gather_pages(new_pool["k"], table_rows),
                            gather_pages(new_pool["k_s"], table_rows)
                            if cfg.kv_quant else None)
        pv = _dequant_pages(gather_pages(new_pool["v"], table_rows),
                            gather_pages(new_pool["v_s"], table_rows)
                            if cfg.kv_quant else None)
        s = pk.shape[1]
        kpos_pre = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        k_valid = jnp.concatenate(
            [kpos_pre < start_len[:, None],
             jnp.arange(t, dtype=jnp.int32)[None, :] < chunk_len[:, None]],
            axis=1)
        out = chunked_attention(
            q,
            jnp.concatenate([pk.astype(k.dtype), k], axis=1),
            jnp.concatenate([pv.astype(v.dtype), v], axis=1),
            positions,
            jnp.concatenate([kpos_pre, positions], axis=1),
            k_valid,
            causal=True,
        )
    y = L.apply_linear(p["wo"], out.reshape(b, t, -1).astype(x.dtype),
                       backend=backend, act=cfg.act_kernel)
    return y, new_pool


def _attend_rows(qh, k_rows, v_rows, valid, scale, k_s=None, v_s=None):
    """One-token attention of ``qh[B,Hkv,grp,Dh]`` against gathered rows
    ``k/v[B,S,Hkv,D*]`` with validity mask ``valid[B,S]``.

    With int8 rows, ``k_s``/``v_s[B,S,Hkv]`` are the per-row dequant scales:
    the dot streams the int8 codes and the scale is applied to the (tiny)
    score/probability tensors instead of a dense dequantized copy."""
    sc = jnp.einsum(
        "bhgd,bshd->bhgs", qh.astype(jnp.float32), k_rows.astype(jnp.float32)
    ) * scale
    if k_s is not None:
        sc = sc * k_s.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    pattn = jax.nn.softmax(sc, axis=-1)
    if v_s is not None:
        pattn = pattn * v_s.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    return jnp.einsum("bhgs,bshd->bhgd", pattn, v_rows.astype(jnp.float32))


def gqa_decode(
    p, x, positions, cache: Dict[str, jax.Array], cfg: ModelConfig, *, backend: str = "auto"
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode against a [B, Smax] cache.  x: [B, 1, D]."""
    b, t, _ = x.shape
    assert t == 1, "decode processes one token"
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hdim
    grp = h // hkv
    q, k, v = _qkv(p, x, positions, cfg, backend)
    lens = cache["lens"]                                   # [B]
    smax = cache["k"].shape[1]
    slot = lens                                            # insert position
    bidx = jnp.arange(b)
    kpos = jnp.arange(smax)[None, :]                       # [1,S]
    valid = kpos <= slot[:, None]
    scale = dh ** -0.5
    qh = q.reshape(b, hkv, grp, dh)

    if cfg.kv_quant:
        kq, ks = kv_quantize_rows(k[:, 0])
        vq, vs = kv_quantize_rows(v[:, 0])
        k_cache = cache["k"].at[bidx, slot].set(kq)
        v_cache = cache["v"].at[bidx, slot].set(vq)
        k_sc = cache["k_s"].at[bidx, slot].set(ks.astype(cache["k_s"].dtype))
        v_sc = cache["v_s"].at[bidx, slot].set(vs.astype(cache["v_s"].dtype))
        out = _attend_rows(qh, k_cache, v_cache, valid, scale,
                           k_s=k_sc, v_s=v_sc)
        new_cache = {"k": k_cache, "v": v_cache, "k_s": k_sc, "v_s": v_sc,
                     "lens": lens + 1}
    else:
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
        out = _attend_rows(qh, k_cache, v_cache, valid, scale)
        new_cache = {"k": k_cache, "v": v_cache, "lens": lens + 1}
    y = L.apply_linear(
        p["wo"], out.reshape(b, 1, h * dh).astype(x.dtype), backend=backend, act=cfg.act_kernel
    )
    return y, new_cache


def init_gqa_cache(cfg: ModelConfig, batch: int, smax: int) -> Dict[str, jax.Array]:
    hkv, dh = cfg.num_kv_heads, cfg.hdim
    if cfg.kv_quant:
        # int8 cache + per-(position, head) scales: halves HBM traffic of the
        # memory-bound decode step (beyond-paper; weights are already int4).
        # Scales stay f32 like the page pools', so the contiguous slab and
        # paged caches hold bit-identical rows under any cfg dtype.
        return {
            "k": jnp.zeros((batch, smax, hkv, dh), jnp.int8),
            "v": jnp.zeros((batch, smax, hkv, dh), jnp.int8),
            "k_s": jnp.zeros((batch, smax, hkv), jnp.float32),
            "v_s": jnp.zeros((batch, smax, hkv), jnp.float32),
            "lens": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, smax, hkv, dh), cfg.jdtype),
        "v": jnp.zeros((batch, smax, hkv, dh), cfg.jdtype),
        "lens": jnp.zeros((batch,), jnp.int32),
    }


def gather_pages(pool: jax.Array, table_rows: jax.Array) -> jax.Array:
    """``pool[num_pages, page_size, ...]`` + page table ``table_rows[B, P]``
    → dense ``[B, P*page_size, ...]`` rows in logical-position order.

    This is the jnp *reference* gather (``paged_attn_impl="gather"``): it
    materializes the full trash-padded table in HBM every step.  The Pallas
    paged-attention kernel (``kernels/paged_attention.py``) indexes the pool
    inside the grid instead and never builds this array.  Re-exported by
    ``serving.kv_cache`` for the pager tests."""
    g = pool[table_rows]
    return g.reshape(g.shape[0], g.shape[1] * g.shape[2], *g.shape[3:])


def _resolve_paged_impl(cfg: ModelConfig, backend: str) -> str:
    """Map (cfg.paged_attn_impl, kernel backend) to a concrete decode impl."""
    impl = cfg.paged_attn_impl
    if impl != "auto":
        return impl
    if backend == "interpret":
        return "pallas_interpret"
    if backend == "pallas" or (
        backend == "auto" and jax.default_backend() == "tpu"
    ):
        return "pallas"
    return "gather"


def gqa_decode_paged(
    p, x, positions, pool: Dict[str, jax.Array], table_rows: jax.Array,
    write_pos: jax.Array, cfg: ModelConfig, *, backend: str = "auto"
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode against a *paged* pool.

    ``pool``: ``{"k"/"v": [num_pages, page_size, Hkv, Dh]}`` shared across
    slots; ``table_rows[B, P]`` maps each slot's logical pages to pool pages
    (unused entries point at the trash page); ``write_pos[B]`` is the logical
    position the new token lands at.  Rows are gathered back into logical
    order, so the math is identical to :func:`gqa_decode` on a contiguous
    ``[B, P*page_size]`` cache.
    """
    b, t, _ = x.shape
    assert t == 1, "decode processes one token"
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hdim
    q, k, v = _qkv(p, x, positions, cfg, backend)
    page_size = pool["k"].shape[1]
    bidx = jnp.arange(b)
    pg = table_rows[bidx, write_pos // page_size]           # [B] pool page ids
    off = write_pos % page_size
    # distinct slots own distinct pages → scatter indices collide only for
    # idle slots, whose table rows all point at the trash page
    if cfg.kv_quant:
        kq, ks = kv_quantize_rows(k[:, 0])
        vq, vs = kv_quantize_rows(v[:, 0])
        new_pool = {
            "k": pool["k"].at[pg, off].set(kq),
            "v": pool["v"].at[pg, off].set(vq),
            "k_s": pool["k_s"].at[pg, off].set(ks.astype(pool["k_s"].dtype)),
            "v_s": pool["v_s"].at[pg, off].set(vs.astype(pool["v_s"].dtype)),
        }
    else:
        new_pool = {
            "k": pool["k"].at[pg, off].set(k[:, 0]),
            "v": pool["v"].at[pg, off].set(v[:, 0]),
        }
    qh = q.reshape(b, hkv, h // hkv, dh)
    scale = dh ** -0.5
    impl = _resolve_paged_impl(cfg, backend)

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as K

        out = K.gqa_paged_attention(
            qh, new_pool["k"], new_pool["v"], table_rows, write_pos + 1,
            new_pool.get("k_s"), new_pool.get("v_s"), sm_scale=scale,
            backend="interpret" if impl == "pallas_interpret" else "pallas",
        )
    else:
        # XLA reference: dense gather of the pool rows (int8 rows gather
        # their scale rows too; _attend_rows dequantizes in-flight)
        k_rows = gather_pages(new_pool["k"], table_rows)    # [B,P*PS,Hkv,Dh]
        v_rows = gather_pages(new_pool["v"], table_rows)
        valid = jnp.arange(k_rows.shape[1])[None, :] <= write_pos[:, None]
        out = _attend_rows(
            qh, k_rows, v_rows, valid, scale,
            k_s=gather_pages(new_pool["k_s"], table_rows) if cfg.kv_quant else None,
            v_s=gather_pages(new_pool["v_s"], table_rows) if cfg.kv_quant else None,
        )
    y = L.apply_linear(
        p["wo"], out.reshape(b, 1, h * dh).astype(x.dtype), backend=backend, act=cfg.act_kernel
    )
    return y, new_pool


def init_gqa_page_pool(cfg: ModelConfig, num_pages: int, page_size: int):
    hkv, dh = cfg.num_kv_heads, cfg.hdim
    shp = (num_pages, page_size, hkv, dh)
    if cfg.kv_quant:
        # int8 rows + per-(position, head) f32 scale pool: halves KV page
        # bytes on the memory-bound decode path (scales are Dh× smaller)
        return {
            "k": jnp.zeros(shp, jnp.int8),
            "v": jnp.zeros(shp, jnp.int8),
            "k_s": jnp.zeros((num_pages, page_size, hkv), jnp.float32),
            "v_s": jnp.zeros((num_pages, page_size, hkv), jnp.float32),
        }
    return {"k": jnp.zeros(shp, cfg.jdtype), "v": jnp.zeros(shp, cfg.jdtype)}


def kv_quantize_rows(x: jax.Array):
    """Symmetric per-row int8 over the trailing dim:
    ``x[..., D] -> (int8[..., D], f32 scale[...])``.  Used for the contiguous
    int8 KV cache (per position, head), the int8 page pools, and the raw
    prefill KV quantized on paged admission."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / amax[..., None] * 127.0),
                 -127, 127).astype(jnp.int8)
    return q, (amax / 127.0)


# ===================================================================== MLA ==
def init_mla(key, cfg: ModelConfig) -> Dict[str, Any]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dt = cfg.jdtype
    ks = jax.random.split(key, 5)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": L.init_linear(ks[0], d, m.q_lora_rank, dt),
        "norm_q": L.init_norm(m.q_lora_rank, "rmsnorm", dt),
        "wq_b": L.init_linear(ks[1], m.q_lora_rank, h * qk_dim, dt),
        "wkv_a": L.init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "norm_kv": L.init_norm(m.kv_lora_rank, "rmsnorm", dt),
        "wkv_b": L.init_linear(
            ks[3], m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim), dt
        ),
        "wo": L.init_linear(ks[4], h * m.v_head_dim, d, dt),
    }


def _mla_q(p, x, positions, cfg: ModelConfig, backend: str):
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = L.apply_linear(p["wq_a"], x, backend=backend, act=cfg.act_kernel)
    q = L.apply_norm(p["norm_q"], q)
    q = L.apply_linear(p["wq_b"], q, backend=backend, act=cfg.act_kernel).reshape(b, t, h, qk)
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_pe = L.apply_rope(q_pe, positions, theta=cfg.rope_theta, variant="standard")
    return q_nope, q_pe


def _mla_latent(p, x, positions, cfg: ModelConfig, backend: str):
    m = cfg.mla
    kv = L.apply_linear(p["wkv_a"], x, backend=backend, act=cfg.act_kernel)
    ckv, k_pe = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank :]
    ckv = L.apply_norm(p["norm_kv"], ckv)
    k_pe = L.apply_rope(
        k_pe[:, :, None, :], positions, theta=cfg.rope_theta, variant="standard"
    )[:, :, 0, :]
    return ckv, k_pe


def mla_prefill(
    p, x, positions, cfg: ModelConfig, *, backend: str = "auto", causal: bool = True
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Expanded (compute-friendly) MLA for prefill; caches the latent."""
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    q_nope, q_pe = _mla_q(p, x, positions, cfg, backend)
    ckv, k_pe = _mla_latent(p, x, positions, cfg, backend)
    kvb = L.apply_linear(p["wkv_b"], ckv, backend=backend, act=cfg.act_kernel).reshape(
        b, t, h, m.qk_nope_head_dim + m.v_head_dim
    )
    k_nope, v = kvb[..., : m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim :]
    q = jnp.concatenate([q_nope, q_pe], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], q_pe.shape)], -1
    )
    # the concat of head-sharded k_nope with replicated broadcast k_pe leaves
    # GSPMD free to split the contraction dim (-> giant score all-reduces in
    # the chunk scans); pin q/k/v to batch-on-data, heads-on-model
    dp = ("pod", "data")
    q = shard_hint(q, dp, None, "model", None)
    k = shard_hint(k, dp, None, "model", None)
    v = shard_hint(v, dp, None, "model", None)
    out = chunked_attention(q, k, v, positions, positions, causal=causal)
    y = L.apply_linear(p["wo"], out.reshape(b, t, -1), backend=backend, act=cfg.act_kernel)
    return y, {"ckv": ckv, "kpe": k_pe, "lens": jnp.full((b,), t, jnp.int32)}


def mla_prefill_chunk(
    p, x, pool: Dict[str, jax.Array], table_rows: jax.Array,
    start_len: jax.Array, chunk_len: jax.Array, cfg: ModelConfig, *,
    backend: str = "auto"
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked MLA prefill against the paged latent pools, absorbed form.

    Same chunk contract as :func:`gqa_prefill_chunk` — positions are
    ``start_len[b] + t``, the chunk's raw latents scatter into the slot's
    pages, padding rows go to trash.  Attention runs absorbed (scores in the
    latent space, like decode) so the prefix pages stream through the Pallas
    grid without ever re-expanding ``wkv_b`` over a dense gathered copy; the
    chunk's own latents are attended raw (pre-quantization).  The absorbed
    projections ride the grouped W4A16 kernel when ``wkv_b`` is int4.
    """
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    positions = _chunk_positions(start_len, t)
    q_nope, q_pe = _mla_q(p, x, positions, cfg, backend)
    ckv_suf, kpe_suf = _mla_latent(p, x, positions, cfg, backend)
    new_pool = _scatter_chunk(pool, {"ckv": ckv_suf, "kpe": kpe_suf},
                              table_rows, start_len, chunk_len, cfg.kv_quant)
    q_lat = _mla_absorb_q_lat(
        p, q_nope.reshape(b * t, h, m.qk_nope_head_dim), cfg, backend
    ).reshape(b, t, h, -1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    impl = _resolve_paged_impl(cfg, backend)

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as K

        o_lat = K.mla_paged_prefill(
            q_lat, q_pe, ckv_suf, kpe_suf,
            new_pool["ckv"], new_pool["kpe"], table_rows, start_len,
            chunk_len, new_pool.get("ckv_s"), new_pool.get("kpe_s"),
            sm_scale=scale,
            backend="interpret" if impl == "pallas_interpret" else "pallas",
        )
    else:
        # XLA oracle: dense gather + in-flight dequant of the latent prefix,
        # suffix raw — same masks as the kernel grid
        pckv = _dequant_pages(gather_pages(new_pool["ckv"], table_rows),
                              gather_pages(new_pool["ckv_s"], table_rows)
                              if cfg.kv_quant else None)
        pkpe = _dequant_pages(gather_pages(new_pool["kpe"], table_rows),
                              gather_pages(new_pool["kpe_s"], table_rows)
                              if cfg.kv_quant else None)
        s = pckv.shape[1]
        ckv_all = jnp.concatenate(
            [pckv.astype(jnp.float32), ckv_suf.astype(jnp.float32)], axis=1)
        kpe_all = jnp.concatenate(
            [pkpe.astype(jnp.float32), kpe_suf.astype(jnp.float32)], axis=1)
        kpos_pre = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        k_pos = jnp.concatenate([kpos_pre, positions], axis=1)
        k_valid = jnp.concatenate(
            [kpos_pre < start_len[:, None],
             jnp.arange(t, dtype=jnp.int32)[None, :] < chunk_len[:, None]],
            axis=1)
        sc = (
            jnp.einsum("bthr,bsr->bhts", q_lat.astype(jnp.float32), ckv_all)
            + jnp.einsum("bthd,bsd->bhts", q_pe.astype(jnp.float32), kpe_all)
        ) * scale
        mask = (k_valid[:, None, None, :]
                & (k_pos[:, None, None, :] <= positions[:, None, :, None]))
        sc = jnp.where(mask, sc, NEG_INF)
        attn = jax.nn.softmax(sc, axis=-1)
        o_lat = jnp.einsum("bhts,bsr->bthr", attn, ckv_all)
    out = _mla_absorb_out(
        p, o_lat.reshape(b * t, h, -1), cfg, backend
    ).reshape(b, t, h * m.v_head_dim)
    y = L.apply_linear(p["wo"], out.astype(x.dtype), backend=backend, act=cfg.act_kernel)
    return y, new_pool


def _mla_absorb_weights(p, cfg: ModelConfig):
    """Split an *fp* ``wkv_b`` into the absorbed key / value projections
    ``(w_k[r,H,nope], w_v[r,H,vdim])``.

    Quantized params never take this path: PTQ (``core.apply.quantize_params``)
    derives stacked int4 absorbed projections ``p["wkv_b_absorbed"]`` instead,
    and :func:`_mla_absorb_q_lat` / :func:`_mla_absorb_out` contract them
    through the grouped W4A16 kernel — a dense dequantized ``wkv_b`` is never
    materialized on a serving path."""
    m = cfg.mla
    h = cfg.num_heads
    from repro.core.quantize import QuantizedTensor

    wkv_b = p["wkv_b"]["w"]
    if isinstance(wkv_b, QuantizedTensor):
        raise TypeError(
            "quantized MLA decode needs p['wkv_b_absorbed'] (stacked int4 "
            "absorbed weights from core.apply.quantize_params); wholesale "
            "dequantization on the serving path is not supported")
    wkv_b = wkv_b.reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    return wkv_b[..., : m.qk_nope_head_dim], wkv_b[..., m.qk_nope_head_dim :]


def _mla_absorb_q_lat(p, q_nope1, cfg: ModelConfig, backend: str) -> jax.Array:
    """Absorb the query: ``q_lat[b,h,r] = q_nope[b,h,n] · w_k[·]`` — heads
    ride the grouped kernel's expert grid axis when the weight is int4."""
    if "wkv_b_absorbed" in p:
        from repro.kernels import ops as K

        wk_t = p["wkv_b_absorbed"]["wk_t"]               # int4 [H, nope, r]
        x = q_nope1.astype(jnp.float32).transpose(1, 0, 2)  # [H, B, nope]
        return K.w4a16_grouped_matmul(x, wk_t, backend=backend, act=cfg.act_kernel).transpose(
            1, 0, 2)
    w_k, _ = _mla_absorb_weights(p, cfg)
    return jnp.einsum(
        "bhn,rhn->bhr", q_nope1.astype(jnp.float32), w_k.astype(jnp.float32)
    )


def _mla_absorb_out(p, o_lat, cfg: ModelConfig, backend: str) -> jax.Array:
    """Project latent attention output back: ``out[b,h,v] = o_lat[b,h,r] ·
    w_v[·]`` — same head-as-expert grouped contraction for int4."""
    if "wkv_b_absorbed" in p:
        from repro.kernels import ops as K

        wv = p["wkv_b_absorbed"]["wv"]                   # int4 [H, r, v]
        x = o_lat.astype(jnp.float32).transpose(1, 0, 2)    # [H, B, r]
        return K.w4a16_grouped_matmul(x, wv, backend=backend, act=cfg.act_kernel).transpose(
            1, 0, 2)
    _, w_v = _mla_absorb_weights(p, cfg)
    return jnp.einsum("bhr,rhv->bhv", o_lat, w_v.astype(jnp.float32))


def _mla_absorbed_attend(p, q_nope, q_pe, ckv, kpe, valid, cfg: ModelConfig,
                         backend: str):
    """Absorbed-form latent attention of a single query token against gathered
    latent rows ``ckv[B,S,r]`` / ``kpe[B,S,dr]`` with mask ``valid[B,S]``."""
    m = cfg.mla
    b = q_nope.shape[0]
    h = cfg.num_heads

    q_lat = _mla_absorb_q_lat(p, q_nope[:, 0], cfg, backend)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    sc = (
        jnp.einsum("bhr,bsr->bhs", q_lat, ckv.astype(jnp.float32))
        + jnp.einsum(
            "bhd,bsd->bhs", q_pe[:, 0].astype(jnp.float32), kpe.astype(jnp.float32)
        )
    ) * scale
    sc = jnp.where(valid[:, None, :], sc, NEG_INF)
    attn = jax.nn.softmax(sc, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", attn, ckv.astype(jnp.float32))
    out = _mla_absorb_out(p, o_lat, cfg, backend)
    return out.reshape(b, 1, h * m.v_head_dim)


def mla_decode(
    p, x, positions, cache, cfg: ModelConfig, *, backend: str = "auto"
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed-form decode: attention runs in the latent space, so the cache
    stays compressed ([B,S,r] instead of [B,S,H,Dh]) — MLA's entire point."""
    b, t, _ = x.shape
    assert t == 1
    q_nope, q_pe = _mla_q(p, x, positions, cfg, backend)    # [B,1,H,*]
    ckv_new, kpe_new = _mla_latent(p, x, positions, cfg, backend)
    lens = cache["lens"]
    bidx = jnp.arange(b)
    ckv = cache["ckv"].at[bidx, lens].set(ckv_new[:, 0])
    kpe = cache["kpe"].at[bidx, lens].set(kpe_new[:, 0])
    smax = ckv.shape[1]
    valid = jnp.arange(smax)[None, :] <= lens[:, None]
    out = _mla_absorbed_attend(p, q_nope, q_pe, ckv, kpe, valid, cfg, backend)
    y = L.apply_linear(p["wo"], out.astype(x.dtype), backend=backend, act=cfg.act_kernel)
    return y, {"ckv": ckv, "kpe": kpe, "lens": lens + 1}


def mla_decode_paged(
    p, x, positions, pool: Dict[str, jax.Array], table_rows: jax.Array,
    write_pos: jax.Array, cfg: ModelConfig, *, backend: str = "auto"
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed-form decode against a paged latent pool
    (``{"ckv": [NP,PS,r], "kpe": [NP,PS,dr]}``); see :func:`gqa_decode_paged`
    for the page-table convention."""
    b, t, _ = x.shape
    assert t == 1
    m = cfg.mla
    h = cfg.num_heads
    q_nope, q_pe = _mla_q(p, x, positions, cfg, backend)
    ckv_new, kpe_new = _mla_latent(p, x, positions, cfg, backend)
    page_size = pool["ckv"].shape[1]
    bidx = jnp.arange(b)
    pg = table_rows[bidx, write_pos // page_size]
    off = write_pos % page_size
    if cfg.kv_quant:
        cq, cs = kv_quantize_rows(ckv_new[:, 0])            # [B,r] → per-row
        kq, ks = kv_quantize_rows(kpe_new[:, 0])
        new_pool = {
            "ckv": pool["ckv"].at[pg, off].set(cq),
            "kpe": pool["kpe"].at[pg, off].set(kq),
            "ckv_s": pool["ckv_s"].at[pg, off].set(
                cs.astype(pool["ckv_s"].dtype)),
            "kpe_s": pool["kpe_s"].at[pg, off].set(
                ks.astype(pool["kpe_s"].dtype)),
        }
    else:
        new_pool = {
            "ckv": pool["ckv"].at[pg, off].set(ckv_new[:, 0]),
            "kpe": pool["kpe"].at[pg, off].set(kpe_new[:, 0]),
        }
    impl = _resolve_paged_impl(cfg, backend)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as K

        kernel_backend = "interpret" if impl == "pallas_interpret" else "pallas"
        q_lat = _mla_absorb_q_lat(p, q_nope[:, 0], cfg, kernel_backend)
        o_lat = K.mla_paged_attention(
            q_lat, q_pe[:, 0], new_pool["ckv"], new_pool["kpe"], table_rows,
            write_pos + 1, new_pool.get("ckv_s"), new_pool.get("kpe_s"),
            sm_scale=scale,
            backend=kernel_backend,
        )
        out = _mla_absorb_out(p, o_lat, cfg, kernel_backend).reshape(
            b, 1, h * m.v_head_dim)
    else:
        ckv = gather_pages(new_pool["ckv"], table_rows)
        kpe = gather_pages(new_pool["kpe"], table_rows)
        if cfg.kv_quant:
            # XLA reference: dequantize the gathered latent rows in-flight
            ckv = ckv.astype(jnp.float32) * gather_pages(
                new_pool["ckv_s"], table_rows).astype(jnp.float32)[..., None]
            kpe = kpe.astype(jnp.float32) * gather_pages(
                new_pool["kpe_s"], table_rows).astype(jnp.float32)[..., None]
        valid = jnp.arange(ckv.shape[1])[None, :] <= write_pos[:, None]
        out = _mla_absorbed_attend(p, q_nope, q_pe, ckv, kpe, valid, cfg,
                                   backend)
    y = L.apply_linear(p["wo"], out.astype(x.dtype), backend=backend, act=cfg.act_kernel)
    return y, new_pool


def init_mla_page_pool(cfg: ModelConfig, num_pages: int, page_size: int):
    m = cfg.mla
    if cfg.kv_quant:
        return {
            "ckv": jnp.zeros((num_pages, page_size, m.kv_lora_rank), jnp.int8),
            "kpe": jnp.zeros((num_pages, page_size, m.qk_rope_head_dim),
                             jnp.int8),
            "ckv_s": jnp.zeros((num_pages, page_size), jnp.float32),
            "kpe_s": jnp.zeros((num_pages, page_size), jnp.float32),
        }
    return {
        "ckv": jnp.zeros((num_pages, page_size, m.kv_lora_rank), cfg.jdtype),
        "kpe": jnp.zeros((num_pages, page_size, m.qk_rope_head_dim), cfg.jdtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, smax: int) -> Dict[str, jax.Array]:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, smax, m.kv_lora_rank), cfg.jdtype),
        "kpe": jnp.zeros((batch, smax, m.qk_rope_head_dim), cfg.jdtype),
        "lens": jnp.zeros((batch,), jnp.int32),
    }
