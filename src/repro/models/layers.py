"""Primitive layers: norms, linears (fp16 or int4-quantized), embeddings, RoPE.

All models are pure pytrees of arrays; a "linear" parameter is either
``{"w": Array[Ci, Co], ("b": Array[Co])}`` or, after SmoothQuant+ PTQ,
``{"w": QuantizedTensor, ...}``.  :func:`apply_linear` dispatches on the leaf
type, so the same model code serves FP16 and W4A16 paths.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import calibration as _calib
from repro.core.quantize import QuantizedTensor
from repro.kernels import ops as kops

Params = Dict[str, Any]


# ---------------------------------------------------------------- linear ----
def init_linear(key, ci: int, co: int, dtype, bias: bool = False, scale: float | None = None) -> Params:
    scale = scale if scale is not None else ci ** -0.5
    p = {"w": (jax.random.normal(key, (ci, co), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((co,), dtype)
    return p


def apply_linear(p: Params, x: jax.Array, *, backend: str = "auto",
                 act: str = "a16") -> jax.Array:
    w = p["w"]
    col = _calib.current_collector()
    if col is not None:
        col.record_input(w, x)
    if isinstance(w, QuantizedTensor):
        y = kops.w4a16_matmul(x, w, backend=backend, act=act)
    else:
        # bf16 dot OUTPUT (MXU still accumulates f32 internally): keeps the
        # GSPMD-inserted row-parallel psums in bf16 — halves TP all-reduce
        # bytes vs an f32-output dot (MaxText default)
        y = jnp.dot(x, w.astype(x.dtype), preferred_element_type=x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ----------------------------------------------------------------- norms ----
def init_norm(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------------- embedding ----
def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def apply_embedding(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def logits_from_embedding(p: Params, x: jax.Array) -> jax.Array:
    return jnp.dot(
        x, p["table"].astype(x.dtype).T, preferred_element_type=jnp.float32
    )


# ------------------------------------------------------------------ RoPE ----
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: [..., Dh]; angles: broadcastable to [..., Dh/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )


def apply_rope(
    x: jax.Array,               # [B, T, H, Dh]
    positions: jax.Array,       # [B, T] int32, or [3, B, T] for mrope
    *,
    theta: float = 1e4,
    variant: str = "standard",
) -> jax.Array:
    dh = x.shape[-1]
    if variant == "none":
        return x
    if variant == "standard":
        inv = rope_freqs(dh, theta)                       # [Dh/2]
        ang = positions[..., None].astype(jnp.float32) * inv  # [B,T,Dh/2]
        return _rotate(x, ang[:, :, None, :])
    if variant == "2d":
        # ChatGLM RoPE-2d: rotary on the first half of head_dim only.
        half = dh // 2
        inv = rope_freqs(half, theta)
        ang = positions[..., None].astype(jnp.float32) * inv
        xr, xp = x[..., :half], x[..., half:]
        return jnp.concatenate([_rotate(xr, ang[:, :, None, :]), xp], axis=-1)
    if variant == "mrope":
        # Qwen2-VL M-RoPE: head_dim split into 3 sections (t, h, w), each
        # rotated with its own position stream.  positions: [3, B, T].
        if positions.ndim == 2:  # text-only fallback: share the stream
            positions = jnp.stack([positions] * 3)
        secs = (dh // 2 // 2, dh // 8, dh // 8)  # t/h/w halves of Dh/2
        inv = rope_freqs(dh, theta)              # [Dh/2]
        parts, start = [], 0
        for s, sec in enumerate(secs):
            p = positions[s][..., None].astype(jnp.float32)  # [B,T,1]
            parts.append(p * inv[start : start + sec])
            start += sec
        if start < inv.shape[0]:
            parts.append(positions[0][..., None].astype(jnp.float32) * inv[start:])
        ang = jnp.concatenate(parts, axis=-1)     # [B,T,Dh/2]
        return _rotate(x, ang[:, :, None, :])
    raise ValueError(f"unknown rope variant {variant!r}")


# ------------------------------------------------------------------ misc ----
def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)
