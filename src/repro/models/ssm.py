"""Sub-quadratic sequence mixers: Mamba2 (chunked SSD) and RWKV6 (Finch).

Mamba2 uses the chunked SSD algorithm — quadratic attention-like form within
length-L chunks, linear state passing between chunks — so training/prefill
memory is O(T·L) instead of O(T²) and only chunk-boundary states materialize.

RWKV6 implements the Finch data-dependent per-channel decay
``w_t = exp(-exp(w0 + lora(x̃_t)))`` with a sequential ``lax.scan`` over time
(compact HLO; per-step state [B,H,K,V]).  Token-shift mixing uses learned
per-channel lerps (the ddlerp LoRA on the *mix* is omitted — documented
simplification; the decay itself, RWKV6's hallmark, is data-dependent).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.hints import shard_hint


# ================================================================= Mamba2 ==
def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    headdim = cfg.ssm_head_dim
    nheads = d_inner // headdim
    return d_inner, headdim, nheads, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig) -> Dict[str, Any]:
    """Projections are kept SEPARATE (z / x / BC / dt) rather than one packed
    in_proj: z and x must be column-sharded head-aligned on the TP axis, while
    B/C are tiny and stay replicated — a packed layout would cut across them."""
    d = cfg.d_model
    d_inner, hp, nh, n = mamba_dims(cfg)
    dt = cfg.jdtype
    ks = jax.random.split(key, 6)
    return {
        "in_z": L.init_linear(ks[0], d, d_inner, dt),
        "in_x": L.init_linear(ks[1], d, d_inner, dt),
        "in_bc": L.init_linear(ks[2], d, 2 * n, dt),
        "in_dt": L.init_linear(ks[3], d, nh, dt),
        "conv_x_w": (jax.random.normal(ks[4], (cfg.conv_kernel, d_inner), jnp.float32) * 0.2).astype(dt),
        "conv_x_b": jnp.zeros((d_inner,), dt),
        "conv_bc_w": (jax.random.normal(ks[5], (cfg.conv_kernel, 2 * n), jnp.float32) * 0.2).astype(dt),
        "conv_bc_b": jnp.zeros((2 * n,), dt),
        "dt_bias": jnp.zeros((nh,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dt),
        "d_skip": jnp.ones((nh,), dt),
        "norm": L.init_norm(d_inner, "rmsnorm", dt),
        "out_proj": L.init_linear(jax.random.fold_in(ks[0], 7), d_inner, d, dt),
    }


def _in_projections(p, xin, cfg, backend):
    z = L.apply_linear(p["in_z"], xin, backend=backend)
    x = L.apply_linear(p["in_x"], xin, backend=backend)
    bc = L.apply_linear(p["in_bc"], xin, backend=backend)
    dt = L.apply_linear(p["in_dt"], xin, backend=backend)
    return z, x, bc, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x: [B,T,C]; w: [K,C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(
    x: jax.Array,      # [B,T,H,P]  (dt-scaled input)
    la: jax.Array,     # [B,T,H]    log-decay per step (negative)
    bm: jax.Array,     # [B,T,N]
    cm: jax.Array,     # [B,T,N]
    h0: jax.Array | None = None,   # [B,H,P,N] initial state
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y [B,T,H,P], final state [B,H,P,N])."""
    b, t, h, p = x.shape
    n = bm.shape[-1]
    lchunk = min(chunk, t)
    tp = -(-t // lchunk) * lchunk
    if tp != t:
        x = jnp.pad(x, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, tp - t), (0, 0)))  # pad decay 0 = no-op
        bm = jnp.pad(bm, ((0, 0), (0, tp - t), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, tp - t), (0, 0)))
    nc = tp // lchunk
    # chunk-major layouts for the scan
    xc = x.reshape(b, nc, lchunk, h, p).transpose(1, 0, 2, 3, 4)
    lac = la.reshape(b, nc, lchunk, h).transpose(1, 0, 2, 3)
    bc = bm.reshape(b, nc, lchunk, n).transpose(1, 0, 2, 3)
    cc = cm.reshape(b, nc, lchunk, n).transpose(1, 0, 2, 3)
    mask = jnp.tril(jnp.ones((lchunk, lchunk), bool))

    def chunk_step(hprev, inp):
        xk, lk, bk, ck = inp                    # [B,L,H,P], [B,L,H], [B,L,N] x2
        xk32 = xk.astype(jnp.float32)
        cum = jnp.cumsum(lk, axis=1)            # Λ_i   [B,L,H]
        total = cum[:, -1, :]                   # [B,H]
        # intra-chunk: y_i += Σ_{j<=i} (C_i·B_j) exp(Λ_i-Λ_j) x_j
        cb = jnp.einsum("bin,bjn->bij", ck.astype(jnp.float32), bk.astype(jnp.float32))
        diff = cum[:, :, None, :] - cum[:, None, :, :]   # [B,i,j,H]
        # mask BEFORE exp: for j>i the diff is positive and exp overflows,
        # which would poison gradients through the where (NaN-grad trap).
        m = jnp.exp(jnp.where(mask[None, :, :, None], diff, -1e30))
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", cb, m, xk32)
        # inter-chunk: y_i += C_i · (exp(Λ_i) H_prev)
        y_inter = jnp.einsum(
            "bih,bin,bhpn->bihp", jnp.exp(cum), ck.astype(jnp.float32), hprev
        )
        # state to end of chunk: H = exp(total) H_prev + Σ_j exp(Λ_L-Λ_j) x_j B_j
        dte = jnp.exp(total[:, None, :] - cum)  # [B,L,H]
        s_c = jnp.einsum("bjh,bjhp,bjn->bhpn", dte, xk32, bk.astype(jnp.float32))
        hnew = hprev * jnp.exp(total)[:, :, None, None] + s_c
        return hnew, y_intra + y_inter

    hinit = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    hlast, ys = jax.lax.scan(chunk_step, hinit, (xc, lac, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, tp, h, p)[:, :t]
    return y.astype(x.dtype), hlast


def mamba2_forward(
    p: Dict[str, Any],
    xin: jax.Array,          # [B,T,D]
    cfg: ModelConfig,
    *,
    backend: str = "auto",
    return_state: bool = False,
):
    d_inner, hp, nh, n = mamba_dims(cfg)
    z, x_raw, bc_raw, dt = _in_projections(p, xin, cfg, backend)
    x = _causal_conv(x_raw, p["conv_x_w"], p["conv_x_b"])
    bc = _causal_conv(bc_raw, p["conv_bc_w"], p["conv_bc_b"])
    bm, cm = jnp.split(bc, [n], axis=-1)
    b_, t_, _ = xin.shape
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))       # [H] negative
    la = dt * a                                        # [B,T,H]
    xh = x.reshape(b_, t_, nh, hp)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    y, hlast = ssd_chunked(xdt.astype(xin.dtype), la, bm, cm)
    y = y + xh.astype(jnp.float32).astype(xin.dtype) * p["d_skip"][None, None, :, None].astype(xin.dtype)
    y = y.reshape(b_, t_, d_inner)
    y = L.apply_norm(p["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(xin.dtype)
    out = L.apply_linear(p["out_proj"], y, backend=backend)
    if not return_state:
        return out
    km1 = cfg.conv_kernel - 1

    def tail(a):
        return a[:, -km1:, :] if t_ >= km1 else jnp.pad(
            a, ((0, 0), (km1 - t_, 0), (0, 0))
        )

    return out, {"h": hlast, "conv_x": tail(x_raw), "conv_bc": tail(bc_raw)}


def mamba2_prefill_chunk(
    p: Dict[str, Any],
    xin: jax.Array,          # [B,T,D] padded chunk
    state: Dict[str, jax.Array],
    lens: jax.Array,         # [B] valid tokens this chunk (rest is padding)
    cfg: ModelConfig,
    *,
    backend: str = "auto",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One chunk of a state-carrying prefill: consume ``lens[b]`` tokens of
    each row on top of ``state`` (SSD state + conv history from the previous
    chunk, zeros on the first) and emit the boundary state for the next.

    Numerics match :func:`mamba2_forward` exactly for a whole prompt fed as
    one full-length chunk: the conv runs over ``[history, chunk]`` so each
    output token sees its true K-1 predecessors, and padding beyond
    ``lens[b]`` is neutralized by zeroing ``dt`` *after* softplus — decay
    ``exp(dt·a) = 1`` and update ``x·dt = 0`` make every padded step a state
    no-op, so the emitted state is the state after exactly ``lens[b]``
    tokens regardless of the bucket's pad length.
    """
    d_inner, hp, nh, n = mamba_dims(cfg)
    b_, t_, _ = xin.shape
    km1 = cfg.conv_kernel - 1
    z, x_raw, bc_raw, dt = _in_projections(p, xin, cfg, backend)
    # conv over [K-1 history, chunk]; drop the history positions afterwards
    stream_x = jnp.concatenate([state["conv_x"], x_raw], axis=1)
    stream_bc = jnp.concatenate([state["conv_bc"], bc_raw], axis=1)
    x = _causal_conv(stream_x, p["conv_x_w"], p["conv_x_b"])[:, km1:]
    bc = _causal_conv(stream_bc, p["conv_bc_w"], p["conv_bc_b"])[:, km1:]
    bm, cm = jnp.split(bc, [n], axis=-1)
    valid = (jnp.arange(t_)[None, :] < lens[:, None])  # [B,T]
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    dt = jnp.where(valid[:, :, None], dt, 0.0)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    la = dt * a
    xh = x.reshape(b_, t_, nh, hp)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    y, hlast = ssd_chunked(xdt.astype(xin.dtype), la, bm, cm, h0=state["h"])
    y = y + xh.astype(jnp.float32).astype(xin.dtype) * p["d_skip"][None, None, :, None].astype(xin.dtype)
    y = y.reshape(b_, t_, d_inner)
    y = L.apply_norm(p["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(xin.dtype)
    out = L.apply_linear(p["out_proj"], y, backend=backend)
    # raw token t sits at stream index K-1+t, so the K-1 tokens ending each
    # row's valid region are stream indices [lens, lens+K-2] — for lens=0
    # that window is exactly the incoming history (state unchanged)
    idx = lens[:, None] + jnp.arange(km1)[None, :]      # [B,K-1]
    tail = lambda s: jnp.take_along_axis(s, idx[:, :, None], axis=1)
    return out, {"h": hlast, "conv_x": tail(stream_x),
                 "conv_bc": tail(stream_bc)}


def mamba2_decode(
    p: Dict[str, Any],
    xin: jax.Array,          # [B,1,D]
    state: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    backend: str = "auto",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-step recurrent update.
    state: {"h": [B,H,P,N], "conv_x": [B,K-1,d_inner], "conv_bc": [B,K-1,2N]}."""
    d_inner, hp, nh, n = mamba_dims(cfg)
    b = xin.shape[0]
    z, x_raw, bc_raw, dt = _in_projections(p, xin, cfg, backend)

    def conv_step(hist, new, w, bias):
        window = jnp.concatenate([hist, new[:, None]], 1)  # [B,K,C]
        out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
        return jax.nn.silu(out + bias.astype(jnp.float32)).astype(new.dtype), window[:, 1:]

    x1, new_conv_x = conv_step(state["conv_x"], x_raw[:, 0], p["conv_x_w"], p["conv_x_b"])
    bc1, new_conv_bc = conv_step(state["conv_bc"], bc_raw[:, 0], p["conv_bc_w"], p["conv_bc_b"])
    b1, c1 = jnp.split(bc1, [n], axis=-1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt1 * a)                             # [B,H]
    xh = x1.reshape(b, nh, hp).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn->bhpn", xh * dt1[..., None], b1.astype(jnp.float32))
    h = state["h"] * dec[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, c1.astype(jnp.float32))
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_inner).astype(xin.dtype)
    y = L.apply_norm(p["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(xin.dtype)
    out = L.apply_linear(p["out_proj"], y, backend=backend)
    new_state = {"h": h, "conv_x": new_conv_x, "conv_bc": new_conv_bc}
    return out, new_state


def init_mamba2_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    d_inner, hp, nh, n = mamba_dims(cfg)
    return {
        "h": jnp.zeros((batch, nh, hp, n), jnp.float32),
        "conv_x": jnp.zeros((batch, cfg.conv_kernel - 1, d_inner), cfg.jdtype),
        "conv_bc": jnp.zeros((batch, cfg.conv_kernel - 1, 2 * n), cfg.jdtype),
    }


# ================================================================== RWKV6 ==
RWKV_LORA = 64


def rwkv_dims(cfg: ModelConfig) -> Tuple[int, int]:
    k = cfg.ssm_head_dim
    return cfg.d_model // k, k  # (heads, head_dim)


def init_rwkv6(key, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    nh, hk = rwkv_dims(cfg)
    dt = cfg.jdtype
    ks = jax.random.split(key, 9)
    return {
        # token-shift mix coefficients (r,k,v,g,w)
        "mix": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dt),
        "wr": L.init_linear(ks[1], d, d, dt),
        "wk": L.init_linear(ks[2], d, d, dt),
        "wv": L.init_linear(ks[3], d, d, dt),
        "wg": L.init_linear(ks[4], d, d, dt),
        "wo": L.init_linear(ks[5], d, d, dt),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(xw @ A) @ B))
        "w0": (jax.random.normal(ks[6], (d,), jnp.float32) * 0.5 - 5.0).astype(dt),
        "w_lora_a": (jax.random.normal(ks[7], (d, RWKV_LORA), jnp.float32) * d**-0.5).astype(dt),
        "w_lora_b": (jax.random.normal(ks[8], (RWKV_LORA, d), jnp.float32) * RWKV_LORA**-0.5).astype(dt),
        "u_bonus": jnp.zeros((d,), dt),
        # RWKV6 uses GroupNorm with one group per head: per-head normalization
        # is local under head-sharded TP (no cross-shard reduction)
        "ln_x": {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)},
    }


def _head_groupnorm(p, y: jax.Array, nh: int, eps: float = 1e-5) -> jax.Array:
    """GroupNorm(groups=heads) over the last dim.  y: [..., D]."""
    shp = y.shape
    yf = y.astype(jnp.float32).reshape(*shp[:-1], nh, shp[-1] // nh)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + eps)
    yf = yf.reshape(shp)
    return (yf * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(y.dtype)


def _rwkv_inputs(p, x, x_prev, backend):
    """x: [B,T,D]; x_prev: [B,T,D] shifted-by-one input."""
    mix = p["mix"].astype(jnp.float32)
    xf, pf = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    mixed = [pf + (xf - pf) * mix[i] for i in range(5)]
    xr, xk, xv, xg, xw = [m.astype(x.dtype) for m in mixed]
    r = L.apply_linear(p["wr"], xr, backend=backend)
    k = L.apply_linear(p["wk"], xk, backend=backend)
    v = L.apply_linear(p["wv"], xv, backend=backend)
    g = L.apply_linear(p["wg"], xg, backend=backend)
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32))
    logw = p["w0"].astype(jnp.float32) + lora @ p["w_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw))                        # [B,T,D] in (0,1)
    return r, k, v, g, w


def rwkv6_forward(
    p: Dict[str, Any],
    xin: jax.Array,          # [B,T,D]
    cfg: ModelConfig,
    *,
    backend: str = "auto",
    return_state: bool = False,
):
    b, t, d = xin.shape
    nh, hk = rwkv_dims(cfg)
    x_prev = jnp.pad(xin, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _rwkv_inputs(p, xin, x_prev, backend)
    rh = r.reshape(b, t, nh, hk).astype(jnp.float32)
    kh = k.reshape(b, t, nh, hk).astype(jnp.float32)
    vh = v.reshape(b, t, nh, hk).astype(jnp.float32)
    wh = w.reshape(b, t, nh, hk)
    u = p["u_bonus"].astype(jnp.float32).reshape(nh, hk)

    def step(s, inp):
        rt, kt, vt, wt = inp                           # [B,H,K] each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = s * wt[..., None] + kv
        return s, out

    s0 = jnp.zeros((b, nh, hk, hk), jnp.float32)
    dp = ("pod", "data")
    # pin the time-major scan operands to (T, batch→data, heads→model, K):
    # without the anchors GSPMD replicates the whole [B,T,D] stream around
    # the sequential scan (8 full-activation all-gathers per layer)
    hint = lambda a: shard_hint(a, None, dp, "model", None)
    s0 = shard_hint(s0, dp, "model", None, None)
    s_last, outs = jax.lax.scan(
        step,
        s0,
        (
            hint(rh.transpose(1, 0, 2, 3)),
            hint(kh.transpose(1, 0, 2, 3)),
            hint(vh.transpose(1, 0, 2, 3)),
            hint(wh.transpose(1, 0, 2, 3)),
        ),
    )
    y = hint(outs).transpose(1, 0, 2, 3).reshape(b, t, d)
    y = _head_groupnorm(p["ln_x"], y.astype(xin.dtype), nh)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(xin.dtype)
    out = L.apply_linear(p["wo"], y, backend=backend)
    if not return_state:
        return out
    return out, {"wkv": s_last, "x_prev": xin[:, -1]}


def rwkv6_decode(
    p: Dict[str, Any],
    xin: jax.Array,          # [B,1,D]
    state: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    backend: str = "auto",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """state: {"wkv": [B,H,K,V] f32, "x_prev": [B,D]}."""
    b, _, d = xin.shape
    nh, hk = rwkv_dims(cfg)
    r, k, v, g, w = _rwkv_inputs(p, xin, state["x_prev"][:, None, :], backend)
    rt = r.reshape(b, nh, hk).astype(jnp.float32)
    kt = k.reshape(b, nh, hk).astype(jnp.float32)
    vt = v.reshape(b, nh, hk).astype(jnp.float32)
    wt = w.reshape(b, nh, hk)
    u = p["u_bonus"].astype(jnp.float32).reshape(nh, hk)
    s = state["wkv"]
    kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
    out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
    s_new = s * wt[..., None] + kv
    y = out.reshape(b, 1, d).astype(xin.dtype)
    y = _head_groupnorm(p["ln_x"], y, nh)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(xin.dtype)
    y = L.apply_linear(p["wo"], y, backend=backend)
    return y, {"wkv": s_new, "x_prev": xin[:, 0]}


def init_rwkv_channel_mix(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.jdtype
    ks = jax.random.split(key, 3)
    return {
        "mix": jax.random.uniform(ks[0], (2, d), jnp.float32).astype(dt),  # (k, r)
        "wk": L.init_linear(ks[1], d, f, dt),
        "wv": L.init_linear(ks[2], f, d, dt),
        "wr": L.init_linear(jax.random.fold_in(ks[0], 1), d, d, dt),
    }


def rwkv_channel_mix(
    p: Dict[str, Any], x: jax.Array, x_prev: jax.Array, *, backend: str = "auto"
) -> jax.Array:
    """Finch FFN: y = sigmoid(Wr x_r) ⊙ Wv relu(Wk x_k)²."""
    mix = p["mix"].astype(jnp.float32)
    xf, pf = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    xk = (pf + (xf - pf) * mix[0]).astype(x.dtype)
    xr = (pf + (xf - pf) * mix[1]).astype(x.dtype)
    k = L.apply_linear(p["wk"], xk, backend=backend)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(
        L.apply_linear(p["wr"], xr, backend=backend).astype(jnp.float32)
    ).astype(x.dtype)
    return r * L.apply_linear(p["wv"], k, backend=backend)


def init_rwkv6_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    nh, hk = rwkv_dims(cfg)
    return {
        "wkv": jnp.zeros((batch, nh, hk, hk), jnp.float32),
        "x_prev": jnp.zeros((batch, cfg.d_model), cfg.jdtype),
    }
