"""Family-dispatching facade over the model zoo.

Gives every arch the same five entry points, so the trainer, serving engine,
and dry-run never branch on family:

  init_model(key, cfg)                          -> params
  loss_fn(params, batch, cfg, ...)              -> scalar loss
  forward_fn(params, batch, cfg, ...)           -> logits
  prefill_fn(params, batch, cfg, smax, ...)     -> (logits, cache)
  decode_fn(params, batch, cache, cfg, ...)     -> (logits, cache)

plus ``input_specs(cfg, shape)`` returning ShapeDtypeStruct stand-ins for the
dry-run (never allocates), and ``init_decode_cache`` / ``cache_specs``.

Batch dicts:
  train   {tokens, labels[, frames][, embeds]}
  prefill {tokens[, frames][, embeds]}
  decode  {token, position}
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm as LM
from repro.models import whisper as W

VLM_PATCHES = 256  # stub: fixed vision-prefix length for qwen2-vl


def init_model(key, cfg: ModelConfig):
    if cfg.encdec:
        return W.init_whisper(key, cfg)
    return LM.init_lm(key, cfg)


def _lm_kw(batch):
    kw = {}
    if "embeds" in batch:
        kw["embeds"] = batch["embeds"]
    return kw


def loss_fn(params, batch: Dict[str, Any], cfg: ModelConfig, *,
            backend: str = "auto", remat: bool = False) -> jax.Array:
    if cfg.encdec:
        return W.whisper_loss(
            params, batch["frames"], batch["tokens"], batch["labels"], cfg,
            backend=backend, remat=remat,
        )
    return LM.lm_loss(
        params, batch["tokens"], batch["labels"], cfg, backend=backend,
        remat=remat, **_lm_kw(batch),
    )


def forward_fn(params, batch, cfg: ModelConfig, *, backend: str = "auto"):
    if cfg.encdec:
        return W.whisper_forward(params, batch["frames"], batch["tokens"], cfg,
                                 backend=backend)
    logits, _ = LM.lm_forward(params, batch["tokens"], cfg, backend=backend,
                              **_lm_kw(batch))
    return logits


def prefill_fn(params, batch, cfg: ModelConfig, smax: int, *,
               backend: str = "auto", last_idx=None, raw_cache: bool = False):
    if cfg.encdec:
        if last_idx is not None or raw_cache:
            raise NotImplementedError("bucketed/raw prefill is decoder-only")
        return W.whisper_prefill(params, batch["frames"], batch["tokens"], cfg,
                                 smax, backend=backend)
    return LM.lm_prefill(params, batch["tokens"], cfg, smax, backend=backend,
                         last_idx=last_idx, raw_cache=raw_cache,
                         **_lm_kw(batch))


def decode_fn(params, batch, cache, cfg: ModelConfig, *,
              backend: str = "auto"):
    if cfg.encdec:
        return W.whisper_decode(params, batch["token"], cache,
                                batch["position"], cfg, backend=backend)
    return LM.lm_decode(params, batch["token"], cache, batch["position"], cfg,
                        backend=backend)


def init_decode_cache(cfg: ModelConfig, batch: int, smax: int, enc_len: int = 0):
    if cfg.encdec:
        return W.init_whisper_cache(cfg, batch, smax, enc_len or smax)
    return LM.init_cache(cfg, batch, smax)


# ---------------------------------------------------------- paged serving ---
# State-leaf kinds a slot can own (see serving/ — the engine generalizes
# "slot state" beyond KV pages):
#   kv_pages   read-write paged KV (attention / MLA / hybrid shared-attn)
#   fixed_rows per-layer O(1) SSM state rows, swapped alongside KV pages
#   shared_ro  refcounted read-only pages (encoder cross-attn K/V)
KV_PAGES = "kv_pages"
FIXED_ROWS = "fixed_rows"
SHARED_RO = "shared_ro"


def state_leaves(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which state-leaf kinds a slot of this config owns."""
    if cfg.encdec:
        return (KV_PAGES, SHARED_RO)
    if cfg.family == "hybrid":
        return (KV_PAGES, FIXED_ROWS)
    return (KV_PAGES,)


def paged_supported(cfg: ModelConfig) -> Tuple[bool, str]:
    """Whether the paged serving cache covers this config (reason if not)."""
    return LM.paged_supported(cfg)


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int):
    """Per-layer KV pools ``[L, num_pages, page_size, ...]`` for the serving
    engine's block-table pager (``repro.serving.kv_cache``).  With
    ``cfg.kv_quant`` the pools are int8 plus per-row f32 scale pools.
    Enc-dec configs additionally carry the read-only encoder page pool
    under ``"enc"``; hybrid configs page only the shared-attention
    applications (one pool layer per group)."""
    if cfg.encdec:
        return W.init_whisper_paged_cache(cfg, num_pages, page_size)
    return LM.init_paged_cache(cfg, num_pages, page_size)


def init_fixed_state(cfg: ModelConfig, batch: int):
    """Fixed-rows state tree ``[M, B, ...]`` (slot axis second) for configs
    whose :func:`state_leaves` include ``fixed_rows``; the same
    :func:`gather_pool_rows` / :func:`scatter_pool_rows` helpers move a
    slot's rows for swap because the slot axis matches the pools' page
    axis."""
    return LM.init_fixed_state(cfg, batch)


def encode_kv_fn(params, frames, cfg: ModelConfig, *, backend: str = "auto"):
    """Encoder pass + per-decoder-layer cross K/V rows
    (``{"xk"/"xv": [L, B, T_enc, Hkv, Dh]}``) for admission into the
    read-only encoder page pool."""
    if not cfg.encdec:
        raise NotImplementedError("encoder K/V is enc-dec only")
    return W.whisper_enc_kv(params, frames, cfg, backend=backend)


def quantize_raw_paged(raw, cfg: ModelConfig):
    """Quantize raw prefill KV to match int8 page pools (no-op unless
    ``cfg.kv_quant``); run before ``serving.kv_cache.write_prefix``."""
    return LM.quantize_raw_paged(raw, cfg)


@jax.jit
def gather_pool_rows(pools, pages: jax.Array):
    """Gather whole pool pages for a slot swap-out.

    ``pools`` leaves are ``[L, num_pages, page_size, ...]`` (any dtype — fp16
    K/V, MLA latents, int8 codes and their f32 ``*_s`` scale leaves alike);
    ``pages[n]`` are the pool page ids the slot owns.  Returns the matching
    ``[L, n, page_size, ...]`` tree, ready for ``jax.device_get`` into a host
    swap buffer.  jit re-specializes per page count; preemption is rare, so
    the handful of traces is cheap."""
    return jax.tree.map(lambda leaf: leaf[:, pages], pools)


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter_pool_rows(pools, rows, pages: jax.Array):
    """Inverse of :func:`gather_pool_rows`: write swapped-out rows back into
    freshly allocated pool pages (swap-in).  ``rows`` leaves are
    ``[L, n, page_size, ...]``; dtypes already match the pools bit-for-bit
    (the swap buffer stores raw codes + scales, never dequantized copies), so
    a resumed slot's cache is exactly what it was when preempted."""
    return jax.tree.map(
        lambda leaf, r: leaf.at[:, pages].set(r.astype(leaf.dtype)),
        pools, rows)


def swap_image_checksum(rows) -> int:
    """CRC-32 over a *host-materialized* swap image (the
    :func:`gather_pool_rows` tree after ``jax.device_get``).

    Folded leaf-by-leaf in ``jax.tree.leaves`` order, so the checksum covers
    every leaf of every pool kind — fp16 K/V, MLA latents, int8 codes and
    their f32 scale leaves alike.  The engine records it when the swap-out
    drain lands and re-verifies at swap-in: a mismatch means the host buffer
    was corrupted while the request waited off-device, and the victim
    re-prefills from tokens instead of resuming poisoned KV state.

    Host-only by design — call it on numpy trees; hashing a live device
    array would force a blocking transfer in the middle of the step loop.
    """
    import zlib

    import numpy as np

    crc = 0
    for leaf in jax.tree.leaves(rows):
        a = np.asarray(leaf)
        crc = zlib.crc32(a.tobytes(), crc)
    return crc


@functools.partial(jax.jit, donate_argnums=(0,))
def copy_pool_page(pools, src: jax.Array, dst: jax.Array):
    """Copy-on-write helper: duplicate pool page(s) ``src`` into ``dst``
    across every leaf (codes and scale pools alike), in place (pools
    donated).  ``src``/``dst`` are int32 scalars or matching ``[n]`` arrays
    (one dispatch covers a whole admission plan's COW set; destinations are
    distinct fresh pages, so the scatter never collides).  The pager's
    ``PagePool.cow`` picks the pages; this moves the device rows so a slot
    gets a private, bit-identical copy of a shared page before writing into
    it."""
    return jax.tree.map(lambda leaf: leaf.at[:, dst].set(leaf[:, src]), pools)


def prefill_chunk_fn(params, batch, cache, table_rows, start_len, chunk_len,
                     cfg: ModelConfig, *, backend: str = "auto",
                     last_idx=None, fixed=None, slots=None,
                     enc_table=None, enc_len=None):
    """Chunked prefill straight into the paged pools: one ``[B, T]`` prompt
    chunk per slot at logical positions ``start_len[b] + t``; KV scatters
    per chunk, attention reads every earlier token (cached prefix and prior
    chunks alike) through ``table_rows``.  Returns (per-row last-token
    logits — meaningful on final chunks — and the updated pools).

    Hybrid configs additionally take/return the fixed-rows state tree
    (``fixed`` + the bucket's ``slots``) — a 3-tuple result; enc-dec
    configs take the slot's encoder page table + valid length."""
    if cfg.encdec:
        return W.whisper_prefill_chunk(
            params, batch["tokens"], cache, start_len, chunk_len, table_rows,
            enc_table, enc_len, cfg, backend=backend, last_idx=last_idx)
    if cfg.family == "hybrid":
        return LM.hybrid_prefill_chunk(
            params, batch["tokens"], cache, fixed, slots, start_len,
            chunk_len, table_rows, cfg, backend=backend, last_idx=last_idx)
    return LM.lm_prefill_chunk(params, batch["tokens"], cache, start_len,
                               chunk_len, table_rows, cfg, backend=backend,
                               last_idx=last_idx, **_lm_kw(batch))


def decode_paged_fn(params, batch, cache, table_rows, cfg: ModelConfig, *,
                    backend: str = "auto", fixed=None, active=None,
                    enc_table=None, enc_len=None):
    """One decode step against paged pools; ``table_rows[B, P]`` maps each
    slot's logical pages to pool pages.  The attention impl is picked by
    ``cfg.paged_attn_impl`` (+ ``backend``): the fused Pallas page-gather
    kernel on TPU / interpret, the jnp dense gather as the XLA reference.

    Hybrid configs take/return the fixed-rows tree plus an ``active[B]``
    mask (rows not decoding keep their SSM state) — a 3-tuple result;
    enc-dec configs take the encoder page table + valid length."""
    if cfg.encdec:
        return W.whisper_decode_paged(
            params, batch["token"], cache, batch["position"], table_rows,
            enc_table, enc_len, cfg, backend=backend)
    if cfg.family == "hybrid":
        return LM.hybrid_decode_paged(
            params, batch["token"], cache, fixed, batch["position"],
            table_rows, active, cfg, backend=backend)
    return LM.lm_decode_paged(params, batch["token"], cache, batch["position"],
                              table_rows, cfg, backend=backend)


# --------------------------------------------------------------- dry-run ----
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell.

    ``decode`` cells: one new token against a ``seq_len`` cache (cache specs
    come from :func:`cache_specs`).  ``audio``/``vlm``: modality frontend is a
    stub — frames/patch embeddings arrive precomputed.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = cfg.jdtype
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, i32)
    emb = lambda *sh: jax.ShapeDtypeStruct(sh, dt)

    if shape.kind == "train":
        if cfg.encdec:
            h = s // 2
            return {"frames": emb(b, h, cfg.d_model), "tokens": tok(b, h),
                    "labels": tok(b, h)}
        spec = {"tokens": tok(b, s), "labels": tok(b, s)}
        if cfg.family == "vlm":
            spec["embeds"] = emb(b, VLM_PATCHES, cfg.d_model)
        return spec
    if shape.kind == "prefill":
        if cfg.encdec:
            h = s // 2
            return {"frames": emb(b, h, cfg.d_model), "tokens": tok(b, h)}
        spec = {"tokens": tok(b, s)}
        if cfg.family == "vlm":
            spec["embeds"] = emb(b, VLM_PATCHES, cfg.d_model)
        return spec
    if shape.kind == "decode":
        return {"token": tok(b, 1), "position": jax.ShapeDtypeStruct((b,), i32)}
    raise ValueError(shape.kind)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Decode-cache ShapeDtypeStruct tree for a decode cell (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.encdec:
        fn = lambda: W.init_whisper_cache(cfg, b, s // 2, s // 2)
    else:
        fn = lambda: LM.init_cache(cfg, b, s)
    return jax.eval_shape(fn)


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment skip rules (see DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; dense-attention arch skipped per assignment"
    return True, ""
