"""Serving engine: continuous batching, slot reuse, quantized path, output
consistency with raw greedy decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import QuantConfig
from repro.core import calibration as C
from repro.core.apply import smoothquant_plus
from repro.models import api
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("codellama-7b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, n, lens=(5, 9, 7, 12), max_tokens=6):
    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                prompt=rng.integers(2, cfg.vocab_size, size=lens[i % len(lens)]).astype(np.int32),
                max_tokens=max_tokens)
        for i in range(n)
    ]


def test_engine_completes_all_requests(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=3, max_seq=40, backend="xla")
    for r in _reqs(cfg, 7):
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == 7
    assert stats.decoded_tokens > 0


def test_continuous_batching_overlaps(setup):
    """More requests than slots must still finish, reusing freed slots."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=40, backend="xla")
    reqs = _reqs(cfg, 5, max_tokens=4)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(len(r.output) >= 1 for r in reqs)
    assert all(r.done_t is not None for r in reqs)


def test_engine_greedy_matches_reference_decode(setup):
    """Engine (greedy) must reproduce a hand-rolled prefill+decode loop."""
    cfg, params = setup
    prompt = np.arange(3, 11).astype(np.int32)
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=32, backend="xla")
    req = Request(uid=0, prompt=prompt, max_tokens=4, temperature=0.0)
    eng.submit(req)
    eng.run_until_drained()

    # reference: single-request prefill + greedy decode
    logits, cache = api.prefill_fn(
        params, {"tokens": jnp.asarray(prompt)[None]}, cfg, 32, backend="xla")
    out = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(3):
        lg, cache = api.decode_fn(
            params,
            {"token": jnp.asarray([[out[-1]]], jnp.int32),
             "position": jnp.asarray([pos], jnp.int32)},
            cache, cfg, backend="xla")
        out.append(int(jnp.argmax(lg, -1)[0]))
        pos += 1
    assert req.output == out


def test_quantized_engine_serves(setup):
    cfg0, params = setup
    cfg = cfg0.with_(dtype="float32")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    batches = C.synthetic_calibration_set(cfg, n_seqs=1, seq_len=16)
    qparams, rep = smoothquant_plus(
        params, cfg, batches, QuantConfig(group_size=32), step=0.5)
    eng = ServingEngine(qparams, cfg, batch_size=2, max_seq=32, backend="xla")
    for r in _reqs(cfg, 3, max_tokens=4):
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == 3


def test_greedy_slot_unaffected_by_hot_neighbor(setup):
    """Regression: the seed engine sampled every slot at the batch-max
    temperature, so a greedy request sharing a step with a hot (t=1.5)
    request produced non-deterministic output.  Per-slot sampling must keep
    the greedy slot token-identical to the single-request reference."""
    cfg, params = setup
    prompt = np.arange(3, 11).astype(np.int32)

    ref = ServingEngine(params, cfg, batch_size=1, max_seq=32, backend="xla")
    ref_req = Request(uid=0, prompt=prompt.copy(), max_tokens=5, temperature=0.0)
    ref.submit(ref_req)
    ref.run_until_drained()

    eng = ServingEngine(params, cfg, batch_size=2, max_seq=32, backend="xla",
                        seed=7)
    greedy = Request(uid=0, prompt=prompt.copy(), max_tokens=5, temperature=0.0)
    hot = Request(uid=1, prompt=np.arange(5, 14).astype(np.int32),
                  max_tokens=5, temperature=1.5)
    eng.submit(greedy)
    eng.submit(hot)
    eng.run_until_drained()
    assert greedy.output == ref_req.output
    assert len(hot.output) >= 1


def test_per_slot_sampling_mixes_greedy_and_stochastic():
    """sample_per_slot: greedy rows are argmax, hot rows follow their own
    temperature (statistically distinguishable from the batch-max behavior)."""
    from repro.serving.sampling import sample, sample_per_slot

    key = jax.random.PRNGKey(0)
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64)) * 3,
                         jnp.float32)
    temps = jnp.asarray([0.0, 1.0], jnp.float32)
    draws = np.array([
        np.asarray(sample_per_slot(logits, k, temps))
        for k in jax.random.split(key, 64)
    ])
    # greedy row: always argmax
    assert (draws[:, 0] == int(jnp.argmax(logits[0]))).all()
    # stochastic row: actually samples (not argmax-locked)
    assert len(set(draws[:, 1].tolist())) > 1
    # scalar-temperature path agrees with per-slot on a uniform batch
    uni = sample(logits, key, temperature=0.0)
    np.testing.assert_array_equal(
        np.asarray(uni),
        np.asarray(sample_per_slot(logits, key, jnp.zeros(2, jnp.float32))))


def test_latency_metadata_recorded(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=32, backend="xla")
    reqs = _reqs(cfg, 2, max_tokens=3)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    for r in reqs:
        assert r.first_token_t is not None and r.done_t is not None
        assert r.done_t >= r.first_token_t >= r.arrival_t
