"""Lazy page growth + preemption/swap: pager grow semantics, randomized
pager stress, engine token-identity under pool pressure, the decode-cap and
drain-guard regressions, and per-request top-k/top-p plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serving import kv_cache as KV
from repro.serving.engine import Request, ServingEngine
from repro.serving.scheduler import Scheduler


# ---------------------------------------------------------------- pager -----
def test_pager_grow_appends_pages():
    pool = KV.PagePool(num_pages=9, page_size=4, batch_size=2,
                       max_pages_per_slot=6)
    a = pool.alloc(0, 2)
    g = pool.grow(0, 1)
    pool.check_invariants()
    assert pool.slot_pages(0) == a + g
    # table prefix extends in place: old logical pages keep their mapping
    assert pool.table()[0, :3].tolist() == a + g
    assert (pool.table()[0, 3:] == KV.TRASH_PAGE).all()
    # alloc still refuses a slot that owns pages; grow is the append path
    with pytest.raises(RuntimeError):
        pool.alloc(0, 1)
    pool.grow(0, 1)                        # slot 0 owns 4, 4 free
    pool.alloc(1, 4)                       # pool drained
    with pytest.raises(RuntimeError):
        pool.grow(1, 1)                    # exhausted
    with pytest.raises(ValueError):
        pool.grow(1, 3)                    # would exceed max_pages_per_slot
    pool.check_invariants()


@pytest.mark.parametrize("faulted", [False, True], ids=["clean", "faulted"])
def test_pager_randomized_stress_interleaved_ops(faulted):
    """Random admit (with prefix-cache match/attach/COW) / decode-grow /
    finish (cache insert) / preempt-swap / swap-in / explicit COW / LRU evict
    sequences hold the pager + cache invariants after every single operation.

    Token sequences are drawn from a tiny alphabet with page-aligned shared
    stems, so block-hash matches, shared attachments, full-aligned-match COW,
    and held-page swaps all actually occur.  The faulted variant runs the
    same walk under a seeded FaultPlan — allocator outages, grow faults (the
    harness rolls back like the scheduler does), forced prefix evictions, and
    a pool-pressure window — and the invariants must still hold after every
    op.

    Slots carry randomly assigned state-leaf kinds beyond paged KV: *hybrid*
    slots own a fixed-rows payload that rides the swap image through
    preempt/abandon/resume, and *encdec* slots own read-only enc-group pages
    that detach under swap holds and reattach on resume — the refcount
    census (refs == listings-across-groups + holds) must hold after every
    op."""
    from repro.serving.faults import FaultPlan, FaultSpec, TransientFault
    from repro.serving.prefix_cache import PrefixCache

    rng = np.random.default_rng(0)
    B, PS, NP, MAXP = 5, 4, 25, 8
    pool = KV.PagePool(num_pages=NP, page_size=PS, batch_size=B,
                       max_pages_per_slot=MAXP, groups=("kv", "enc"))
    cache = PrefixCache(pool, PS, mode="stress")
    sched = Scheduler(page_size=PS, max_seq=MAXP * PS)
    plan = None
    if faulted:
        plan = FaultPlan([
            FaultSpec("page_alloc", prob=0.10, times=None),
            FaultSpec("page_grow", prob=0.15, times=None),
            FaultSpec("prefix_evict", prob=0.20, times=None),
            FaultSpec("pool_pressure", step=100, value=4, duration=80),
        ], seed=1)
        pool.faults = plan
        cache.faults = plan
    stems = [list(rng.integers(0, 3, 8)) for _ in range(3)]   # shared prefixes
    live: dict[int, dict] = {}             # slot -> {tokens, written, kind}
    swapped: list[dict] = []               # swap states
    fixed: dict[int, float] = {}           # hybrid slots' fixed-rows payload
    roundtrips = {"hybrid": 0, "encdec": 0}

    def admit(slot):
        toks = stems[int(rng.integers(0, 3))] + list(
            rng.integers(0, 3, int(rng.integers(0, 9))))
        t = len(toks)
        evicts_before = plan.injected["prefix_evict"] if plan else 0
        matched, mtok = cache.match(toks)
        full = bool(matched) and mtok == t
        total = pool.pages_needed(t + 1)
        fresh = total - len(matched) + (1 if full else 0)
        pinned = sum(1 for p in matched if pool.page_ref(p) == 0)
        # the scheduler's diagnostic twin must charge exactly what this
        # admission takes from the pool (fresh allocations plus the
        # matched-but-unreferenced pages the attach pins) — pages_needed
        # and plan() share one arithmetic path, asserted against the
        # harness's independent bookkeeping at every admission state.
        # A fired prefix_evict fault voids the twin: the harness saw a
        # forced miss, while referenced matched pages survive in the index
        # for the (non-probing) diagnostic to find.
        req = Request(uid=slot, prompt=np.asarray(toks, np.int32),
                      max_tokens=1)
        if plan is None or plan.injected["prefix_evict"] == evicts_before:
            assert sched.pages_needed(req, pool, cache) == fresh + pinned
        if total > MAXP or not pool.can_alloc(fresh):
            return
        if matched:
            pool.attach(slot, matched)
        if full:
            # last page goes private; the hold mirrors the engine pinning
            # the src until its device rows are copied
            src, _ = pool.cow(slot, len(matched) - 1, hold_src=True)
            pool.check_invariants()
            pool.drop_hold(src)
        if fresh - (1 if full else 0):
            try:
                pool.grow(slot, fresh - (1 if full else 0))
            except TransientFault:
                # mirror the scheduler's mid-plan rollback: release whatever
                # this aborted admission attached/copied and walk away
                pool.free_slot(slot)
                return
        # state leaves beyond paged KV: a hybrid slot carries a fixed-rows
        # payload (not paged — it rides swap images), an encdec slot owns
        # read-only enc-group pages next to its KV pages
        kind = ("kv", "hybrid", "encdec")[int(rng.integers(0, 3))]
        if kind == "encdec":
            enc = 1 + int(rng.integers(0, 2))
            if not pool.can_alloc(enc):
                kind = "kv"
            else:
                try:
                    pool.grow(slot, enc, group="enc")
                except TransientFault:
                    pool.free_slot(slot)
                    return
        if kind == "hybrid":
            fixed[slot] = float(rng.standard_normal())
        cache.insert(toks, pool.slot_pages(slot), t // PS)
        live[slot] = {"tokens": list(toks), "written": t, "kind": kind}

    ops_hit = set()
    for i in range(500):
        if plan is not None:
            plan.begin_step(i)
        op = rng.choice(["admit", "decode", "finish", "preempt", "swap_in",
                         "cow", "evict", "abandon"])
        slot = int(rng.integers(0, B))
        if op == "admit" and slot not in live:
            admit(slot)
        elif op == "decode" and slot in live:
            st = live[slot]
            cap = len(pool.slot_pages(slot)) * PS
            if st["written"] + 1 > cap:
                if cap // PS >= MAXP or not pool.can_alloc(1):
                    continue
                try:
                    pool.grow(slot, 1)
                except TransientFault:
                    continue               # engine behavior: retry next step
            st["tokens"].append(int(rng.integers(0, 3)))
            st["written"] += 1
            if st["kind"] == "hybrid":
                fixed[slot] += 1.0         # recurrent state advances
        elif op == "finish" and slot in live:
            st = live.pop(slot)
            fixed.pop(slot, None)
            cache.insert(st["tokens"], pool.slot_pages(slot),
                         st["written"] // PS)
            pool.free_slot(slot)           # releases every group's pages
            assert pool.slot_pages(slot, "enc") == []
        elif op == "preempt" and live:
            victim = max(live)             # any deterministic choice works
            kept, private = pool.split_for_swap(victim)
            # shared / cached pages are never part of the swap image
            assert all(pool.page_ref(p) > 1 or pool.is_cached(p)
                       for _, p in kept)
            pool.swap_out(victim, (kept, private))
            for _, p in kept:              # ...and stay pinned (un-evictable)
                assert pool.page_ref(p) > 0
            st = dict(live.pop(victim), kept=kept,
                      private_lis=[li for li, _ in private])
            if st["kind"] == "hybrid":
                # fixed rows ride the host swap image, not the pager
                st["fx"] = fixed.pop(victim)
            elif st["kind"] == "encdec":
                # read-only pages never leave the device: refs become holds
                st["enc_held"] = pool.detach_group(victim, "enc")
                assert pool.slot_pages(victim, "enc") == []
                for p in st["enc_held"]:
                    assert pool.held()[p] > 0
            swapped.append(st)
        elif op == "swap_in" and swapped:
            st = swapped[0]
            idle = [s for s in range(B) if s not in live]
            if idle and pool.can_alloc(len(st["private_lis"])):
                pool.swap_in(idle[0], st["kept"], st["private_lis"])
                if st["kind"] == "encdec":
                    pool.reattach_group(idle[0], "enc", st["enc_held"])
                    assert pool.slot_pages(idle[0], "enc") == st["enc_held"]
                elif st["kind"] == "hybrid":
                    fixed[idle[0]] = st["fx"]     # bit-exact round trip
                if st["kind"] != "kv":
                    roundtrips[st["kind"]] += 1
                live[idle[0]] = {"tokens": st["tokens"],
                                 "written": st["written"],
                                 "kind": st["kind"]}
                swapped.pop(0)
        elif op == "abandon" and swapped:
            # a swapped request dies (deadline expiry / cancel): kept pages
            # lose their swap holds, detached enc pages too — cached pages
            # turn evictable, uncached ones return to the free list
            st = swapped.pop(int(rng.integers(0, len(swapped))))
            for _, p in st["kept"]:
                pool.drop_hold(p)
            pool.drop_group_holds(st.get("enc_held", []))
        elif op == "cow" and live:
            # explicit COW of any shared/cached page a live slot lists
            cands = [(s, li, p) for s in live
                     for li, p in enumerate(pool.slot_pages(s))
                     if pool.page_ref(p) > 1 or pool.is_cached(p)]
            if cands and pool.can_alloc(1):
                s, li, p = cands[int(rng.integers(0, len(cands)))]
                old, new = pool.cow(s, li)
                assert old == p and pool.page_ref(new) == 1
                assert not pool.is_cached(new)
        elif op == "evict":
            cache.evict_one()
        ops_hit.add(op)
        pool.check_invariants()
    # the randomized walk must actually exercise the whole op surface,
    # including fixed-rows and enc-group slots through full swap cycles
    assert ops_hit == {"admit", "decode", "finish", "preempt", "swap_in",
                       "cow", "evict", "abandon"}
    assert roundtrips["hybrid"] > 0 and roundtrips["encdec"] > 0
    assert cache.stats.hits > 0 and cache.stats.evicted_pages > 0
    if plan is not None:
        # the chaos actually happened — and every fire is in the diff log
        for site in ("page_alloc", "page_grow", "prefix_evict"):
            assert plan.injected[site] > 0, f"{site} never fired"
        assert len(plan.log) == plan.total_injected
    # conservation: every page is free, referenced (any group, incl. pages
    # held by in-flight swap states), or evictable-cached
    referenced = {p for s in range(B) for g in pool.groups
                  for p in pool.slot_pages(s, g)}
    referenced |= {p for st in swapped for _, p in st["kept"]}
    referenced |= {p for st in swapped for p in st.get("enc_held", [])}
    evictable = cache.evictable_count()
    assert len(referenced) + pool.free_pages + evictable == pool.num_pages - 1


def test_scheduler_lazy_reserves_prompt_plus_one():
    from collections import deque
    pool = KV.PagePool(33, 4, batch_size=4, max_pages_per_slot=8)
    lazy = Scheduler(page_size=4, max_seq=32)                  # default lazy
    worst = Scheduler(page_size=4, max_seq=32, reservation="worstcase")
    req = Request(uid=0, prompt=np.arange(2, 9, dtype=np.int32),  # 7 tokens
                  max_tokens=16)
    assert lazy.pages_needed(req, pool) == 2                   # 8 tokens
    assert worst.pages_needed(req, pool) == 6                  # 23 tokens
    # watermark: with reserve=3 the head must leave 3 free pages behind
    tight = KV.PagePool(5, 4, batch_size=4, max_pages_per_slot=4)  # 4 free
    q = deque([req])
    assert lazy.plan(q, [0, 1], tight, reserve=3) == []
    assert len(q) == 1
    buckets = lazy.plan(q, [0, 1], tight, reserve=2)
    assert sum(len(b.reqs) for b in buckets) == 1


# ------------------------------------------------------- engine pressure ----
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("codellama-7b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mixed_reqs(cfg, n=6, max_tokens=8, seed=5):
    rng = np.random.default_rng(seed)
    lens = (3, 7, 10, 5)
    return [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        size=lens[i % 4]).astype(np.int32),
                    max_tokens=max_tokens)
            for i in range(n)]


def test_preempting_engine_token_identical_to_roomy(setup):
    """Acceptance: under a pool too small for the batch's worst case, the
    lazy engine preempts (swap-out + requeue at head) yet produces greedy
    outputs token-identical to an unconstrained engine — preemption is a pure
    scheduling effect, never a correctness one."""
    cfg, params = setup
    roomy_reqs = _mixed_reqs(cfg)
    roomy = ServingEngine(params, cfg, batch_size=3, max_seq=24, page_size=4,
                          backend="xla")
    for r in roomy_reqs:
        roomy.submit(r)
    st_roomy = roomy.run_until_drained()
    assert st_roomy.preemptions == 0           # default pool: no pressure
    assert st_roomy.grown_pages > 0            # but growth is exercised

    tight_reqs = _mixed_reqs(cfg)
    tight = ServingEngine(params, cfg, batch_size=3, max_seq=24, page_size=4,
                          num_pages=1 + 7, backend="xla")
    for r in tight_reqs:
        tight.submit(r)
    st = tight.run_until_drained()
    assert st.completed == len(tight_reqs)
    assert st.preemptions > 0 and st.resumes == st.preemptions
    assert st.swapped_out_bytes == st.swapped_in_bytes > 0
    for a, b in zip(roomy_reqs, tight_reqs):
        assert a.output == b.output
    tight.pager.check_invariants()
    assert tight.pager.free_pages == tight.pager.num_pages - 1


def test_preempting_engine_int8_pools_bit_exact(setup):
    """Swap-out/swap-in round-trips the int8 codes + f32 scale leaves
    verbatim: the kv_quant engine under pressure stays token-identical."""
    cfg, _ = setup
    cfg = cfg.with_(dtype="float32", kv_quant=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    ref_reqs, tight_reqs = _mixed_reqs(cfg, n=5), _mixed_reqs(cfg, n=5)
    ref = ServingEngine(params, cfg, batch_size=3, max_seq=24, page_size=4,
                        backend="xla")
    for r in ref_reqs:
        ref.submit(r)
    ref.run_until_drained()
    tight = ServingEngine(params, cfg, batch_size=3, max_seq=24, page_size=4,
                          num_pages=1 + 7, backend="xla")
    for r in tight_reqs:
        tight.submit(r)
    st = tight.run_until_drained()
    assert st.preemptions > 0
    for a, b in zip(ref_reqs, tight_reqs):
        assert a.output == b.output


def test_lazy_engine_mla_pressure_smoke():
    """Growth + preemption also covers the MLA latent page pools."""
    cfg = get_config("deepseek-v2-236b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_reqs(cfg, n=4, max_tokens=6)
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=16, page_size=4,
                        num_pages=1 + 5, backend="xla")
    for r in reqs:
        eng.submit(r)
    st = eng.run_until_drained()
    assert st.completed == 4
    assert st.grown_pages > 0
    eng.pager.check_invariants()


def test_worstcase_reservation_mode_never_grows(setup):
    cfg, params = setup
    reqs = _mixed_reqs(cfg, n=4)
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=24, page_size=4,
                        backend="xla", reservation="worstcase")
    for r in reqs:
        eng.submit(r)
    st = eng.run_until_drained()
    assert st.completed == 4
    assert st.grown_pages == 0 and st.preemptions == 0


# ------------------------------------------------------------ regressions ---
def test_decode_cap_request_fills_all_positions(setup):
    """Regression (off-by-one): a request may write every one of the S cache
    positions.  prompt = S-2 leaves two decode writes (positions S-2 and
    S-1), so with the first prefill-sampled token the output is 3 tokens —
    the old ``pos >= S - 1`` cap freed the slot one write early."""
    cfg, params = setup
    S = 16
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=S, page_size=8,
                        backend="xla", eos_id=-1)          # eos can't trip
    req = Request(uid=0, prompt=np.arange(2, 2 + S - 2).astype(np.int32),
                  max_tokens=8)
    eng.submit(req)
    st = eng.run_until_drained()
    assert st.completed == 1
    assert len(req.output) == 3                 # first token + 2 decode steps
    # the longest admissible prompt (S-1, submit's bound) still gets 2 tokens
    eng2 = ServingEngine(params, cfg, batch_size=1, max_seq=S, page_size=8,
                         backend="xla", eos_id=-1)
    req2 = Request(uid=1, prompt=np.arange(2, 2 + S - 1).astype(np.int32),
                   max_tokens=8)
    eng2.submit(req2)
    eng2.run_until_drained()
    assert len(req2.output) == 2


def test_run_until_drained_raises_on_stalled_admission(setup):
    """Regression (livelock): a head that can never be admitted used to spin
    forever because ``stats.steps`` only counted decoding steps.  The drain
    now detects the idle iteration and raises, naming the blocked request."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=16, page_size=4,
                        num_pages=9, backend="xla")
    eng.pager._free = eng.pager._free[:1]      # simulate a page leak: 1 left
    eng.submit(Request(uid=42, prompt=np.arange(2, 9).astype(np.int32),
                       max_tokens=2))          # needs 2 pages
    with pytest.raises(RuntimeError, match="uid=42"):
        eng.run_until_drained()
    assert eng.stats.idle_steps == 1


# ----------------------------------------------------------- top-k / top-p --
def test_sample_per_slot_per_row_top_k_top_p():
    from repro.serving.sampling import sample_per_slot

    logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 64)) * 3,
                         jnp.float32)
    temps = jnp.asarray([1.5, 1.5, 1.5], jnp.float32)
    tks = jnp.asarray([1, 0, 0], jnp.int32)
    tps = jnp.asarray([1.0, 1e-6, 1.0], jnp.float32)
    draws = np.array([
        np.asarray(sample_per_slot(logits, k, temps, tks, tps))
        for k in jax.random.split(jax.random.PRNGKey(0), 64)
    ])
    argmax = np.asarray(jnp.argmax(logits, -1))
    # row 0: top_k=1 collapses a hot distribution to argmax
    assert (draws[:, 0] == argmax[0]).all()
    # row 1: top_p→0 keeps only the nucleus head == argmax
    assert (draws[:, 1] == argmax[1]).all()
    # row 2: unfiltered hot row actually samples
    assert len(set(draws[:, 2].tolist())) > 1


def test_scalar_and_per_row_filters_agree():
    from repro.serving.sampling import sample, sample_per_slot

    key = jax.random.PRNGKey(3)
    logits = jnp.asarray(np.random.default_rng(1).normal(size=(4, 32)) * 2,
                         jnp.float32)
    a = sample(logits, key, temperature=0.7, top_k=5, top_p=0.9)
    b = sample_per_slot(logits, key, jnp.full(4, 0.7),
                        jnp.full(4, 5, jnp.int32), jnp.full(4, 0.9))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_plumbs_top_k_including_first_token(setup):
    """End-to-end: a hot-temperature request with top_k=1 must be
    token-identical to greedy — only possible if the engine forwards the
    request's top_k to both the prefill first-token sample and every decode
    sample."""
    cfg, params = setup
    prompt = np.arange(3, 11).astype(np.int32)
    ref = ServingEngine(params, cfg, batch_size=1, max_seq=32, backend="xla")
    greedy = Request(uid=0, prompt=prompt.copy(), max_tokens=5,
                     temperature=0.0)
    ref.submit(greedy)
    ref.run_until_drained()

    eng = ServingEngine(params, cfg, batch_size=1, max_seq=32, backend="xla",
                        seed=9)
    hot = Request(uid=0, prompt=prompt.copy(), max_tokens=5, temperature=2.0,
                  top_k=1)
    eng.submit(hot)
    eng.run_until_drained()
    assert hot.output == greedy.output
    # and an unfiltered hot request does diverge (the plumbing isn't a no-op)
    eng2 = ServingEngine(params, cfg, batch_size=1, max_seq=32, backend="xla",
                         seed=9)
    wild = Request(uid=0, prompt=prompt.copy(), max_tokens=5, temperature=2.0)
    eng2.submit(wild)
    eng2.run_until_drained()
    assert wild.output != greedy.output
