"""Per-architecture smoke tests: reduced config, one forward + one loss/grad
step + one prefill→decode round trip on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api

B, T = 2, 16


def _batch(cfg, kind="train"):
    k = jax.random.PRNGKey(0)
    toks = jax.random.randint(k, (B, T), 0, cfg.vocab_size, jnp.int32)
    if cfg.encdec:
        frames = jax.random.normal(k, (B, T, cfg.d_model), jnp.float32).astype(cfg.jdtype)
        b = {"frames": frames, "tokens": toks}
    elif cfg.family == "vlm":
        emb = jax.random.normal(k, (B, 4, cfg.d_model), jnp.float32).astype(cfg.jdtype)
        b = {"tokens": toks, "embeds": emb}
    else:
        b = {"tokens": toks}
    if kind == "train":
        b["labels"] = jnp.roll(toks, -1, axis=1)
    return b


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    cfg = get_config(request.param, smoke=True)
    params = api.init_model(jax.random.PRNGKey(1), cfg)
    return cfg, params


def test_forward_shapes_and_finite(arch):
    cfg, params = arch
    logits = api.forward_fn(params, _batch(cfg, "prefill"), cfg, backend="xla")
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_train_step_grads_finite(arch):
    cfg, params = arch
    batch = _batch(cfg, "train")
    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, batch, cfg, backend="xla")
    )(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # loss should be near ln(V) for random init
    assert 0.1 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size)


def test_prefill_decode_roundtrip(arch):
    cfg, params = arch
    smax = T + 4
    batch = _batch(cfg, "prefill")
    logits, cache = api.prefill_fn(params, batch, cfg, smax, backend="xla")
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), T, jnp.int32)
    logits2, cache2 = api.decode_fn(
        params, {"token": tok, "position": pos}, cache, cfg, backend="xla"
    )
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())


def test_prefill_matches_forward_last_token(arch):
    """Prefill's last-token logits must agree with the teacher-forced forward."""
    cfg, params = arch
    batch = _batch(cfg, "prefill")
    fwd = api.forward_fn(params, batch, cfg, backend="xla")[:, -1]
    pre, _ = api.prefill_fn(params, batch, cfg, T + 4, backend="xla")
    np.testing.assert_allclose(
        np.asarray(fwd, np.float32), np.asarray(pre, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_decode_consistent_with_forward(arch):
    """Greedy decode of position T must match forward on the extended seq."""
    cfg, params = arch
    if cfg.encdec:
        pytest.skip("enc-dec covered by roundtrip")
    batch = _batch(cfg, "prefill")
    logits, cache = api.prefill_fn(params, batch, cfg, T + 4, backend="xla")
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    ext = jnp.concatenate([batch["tokens"], nxt[:, None]], axis=1)
    b2 = dict(batch, tokens=ext)
    if "embeds" in b2:
        b2["embeds"] = batch["embeds"]
    full = api.forward_fn(params, b2, cfg, backend="xla")[:, -1]
    dec, _ = api.decode_fn(
        params, {"token": nxt[:, None], "position": jnp.full((B,), T, jnp.int32)},
        cache, cfg, backend="xla",
    )
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        rtol=8e-2, atol=8e-2,
    )


@pytest.mark.slow  # ~20s: replays the prompt token-by-token through 2 caches
def test_kv8_decode_close_to_bf16():
    """int8 KV cache (beyond-paper) must track the full-precision decode."""
    cfg = get_config("codellama-7b", smoke=True).with_(dtype="float32")
    params = api.init_model(jax.random.PRNGKey(1), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (B, T), 0,
                                          cfg.vocab_size, jnp.int32)}
    logits, _ = api.prefill_fn(params, batch, cfg, T + 4, backend="xla")
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), T, jnp.int32)

    def decode_with(cfg_v):
        cache = api.init_decode_cache(cfg_v, B, T + 4)
        # replay prompt token-by-token so both paths use the decode cache
        c = cache
        for i in range(T):
            lg, c = api.decode_fn(
                params, {"token": batch["tokens"][:, i:i+1],
                         "position": jnp.full((B,), i, jnp.int32)},
                c, cfg_v, backend="xla")
        lg, _ = api.decode_fn(params, {"token": tok, "position": pos}, c,
                              cfg_v, backend="xla")
        return np.asarray(lg, np.float32)

    full = decode_with(cfg)
    kv8 = decode_with(cfg.with_(kv_quant=True))
    rel = np.linalg.norm(kv8 - full) / np.linalg.norm(full)
    assert rel < 0.05, f"kv8 rel err {rel}"
    assert (kv8.argmax(-1) == full.argmax(-1)).mean() > 0.9
