"""State leaves beyond paged KV: single-step SSM decode parity, hybrid
(zamba2) and encoder-decoder (whisper) engine identity under continuous
batching with preemption/swap, named rejection of unsupported mixers, and
the fixed_drain / enc_evict fault sites."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models import ssm
from repro.serving.engine import (Request, ServingEngine,
                                  RejectedRequest, UnsupportedModelError)
from repro.serving.faults import FaultPlan, FaultSpec


@pytest.fixture(scope="module")
def zamba():
    cfg = get_config("zamba2-7b", smoke=True)
    return cfg, api.init_model(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def whisper():
    cfg = get_config("whisper-medium", smoke=True)
    return cfg, api.init_model(jax.random.PRNGKey(1), cfg)


def _tol(cfg):
    # parity holds to fp accumulation error at the model dtype: the chunked
    # SSD scan and the token recurrence order the same ops differently
    if cfg.jdtype == jnp.bfloat16:
        return dict(rtol=5e-2, atol=5e-2)
    return dict(rtol=2e-4, atol=2e-4)


# ===================================================== single-step parity ==
def test_mamba2_prefill_state_matches_step_recurrence():
    """Chunked-SSD prefill (full forward AND state-carrying chunks) must
    land on the same final state as feeding the prompt token-by-token
    through the decode recurrence."""
    cfg = get_config("zamba2-7b", smoke=True)
    p = ssm.init_mamba2(jax.random.PRNGKey(3), cfg)
    t = 11
    x = (jax.random.normal(jax.random.PRNGKey(4), (1, t, cfg.d_model),
                           jnp.float32) * 0.5).astype(cfg.jdtype)

    out_full, st_full = ssm.mamba2_forward(p, x, cfg, backend="xla",
                                           return_state=True)

    st = ssm.init_mamba2_state(cfg, 1)
    outs = []
    for i in range(t):
        o, st = ssm.mamba2_decode(p, x[:, i:i + 1], st, cfg, backend="xla")
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)

    tol = _tol(cfg)
    np.testing.assert_allclose(np.asarray(out_full, np.float32),
                               np.asarray(out_step, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st_full["h"]),
                               np.asarray(st["h"]), **tol)
    for k in ("conv_x", "conv_bc"):   # raw conv history: same values exactly
        np.testing.assert_allclose(np.asarray(st_full[k], np.float32),
                                   np.asarray(st[k], np.float32), **tol)

    # state-carrying chunked prefill (the engine path), ragged last chunk
    st_c = ssm.init_mamba2_state(cfg, 1)
    x_pad = jnp.pad(x, ((0, 0), (0, 1), (0, 0)))
    for c in range(3):
        lens = jnp.asarray([4 if c < 2 else 3], jnp.int32)
        _, st_c = ssm.mamba2_prefill_chunk(
            p, x_pad[:, c * 4:(c + 1) * 4], st_c, lens, cfg, backend="xla")
    np.testing.assert_allclose(np.asarray(st_c["h"]),
                               np.asarray(st["h"]), **tol)
    for k in ("conv_x", "conv_bc"):
        np.testing.assert_allclose(np.asarray(st_c[k], np.float32),
                                   np.asarray(st[k], np.float32), **tol)


def test_rwkv6_single_step_matches_full_scan():
    """rwkv6_decode iterated from the zero state must reproduce the full
    lax.scan forward — outputs per step and the final (wkv, x_prev)."""
    cfg = get_config("rwkv6-7b", smoke=True)
    p = ssm.init_rwkv6(jax.random.PRNGKey(5), cfg)
    t = 9
    x = (jax.random.normal(jax.random.PRNGKey(6), (1, t, cfg.d_model),
                           jnp.float32) * 0.5).astype(cfg.jdtype)

    out_full, st_full = ssm.rwkv6_forward(p, x, cfg, backend="xla",
                                          return_state=True)

    st = ssm.init_rwkv6_state(cfg, 1)
    outs = []
    for i in range(t):
        o, st = ssm.rwkv6_decode(p, x[:, i:i + 1], st, cfg, backend="xla")
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)

    tol = _tol(cfg)
    np.testing.assert_allclose(np.asarray(out_full, np.float32),
                               np.asarray(out_step, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st_full["wkv"]),
                               np.asarray(st["wkv"]), **tol)
    np.testing.assert_allclose(np.asarray(st_full["x_prev"], np.float32),
                               np.asarray(st["x_prev"], np.float32), **tol)


# ======================================================= engine identity ==
def _ref_outputs(params, cfg, reqs, max_seq=32):
    """Unbatched single-request reference: a B=1 engine per request (same
    code path, no batching / preemption effects)."""
    outs = []
    for r in reqs:
        eng = ServingEngine(params, cfg, batch_size=1, max_seq=max_seq,
                            backend="xla")
        rr = Request(uid=r.uid, prompt=r.prompt, max_tokens=r.max_tokens,
                     frames=r.frames)
        eng.submit(rr)
        eng.run_until_drained(max_steps=300)
        assert rr.finish_reason in ("completed", "length"), rr.finish_reason
        outs.append(list(rr.output))
    return outs


def test_zamba2_engine_token_identical_under_preemption(zamba):
    """Hybrid continuous batching on a tight pool: natural preemption swaps
    fixed-rows state to host and back bit-exactly — greedy outputs identical
    to the unbatched reference.  A fixed_drain fault delays one image's
    host materialization a step; resume must still round-trip it."""
    cfg, params = zamba
    rng = np.random.default_rng(3)
    lens = (5, 9, 7, 12)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        lens[i % 4]).astype(np.int32),
                    max_tokens=6)
            for i in range(5)]
    ref = _ref_outputs(params, cfg, reqs)

    plan = FaultPlan([FaultSpec("fixed_drain", op=0, times=1)], seed=0)
    eng = ServingEngine(params, cfg, batch_size=3, max_seq=24, page_size=4,
                        num_pages=1 + 7, backend="xla",
                        max_prefill_tokens=8, fault_plan=plan)
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained(max_steps=600)
    assert [list(r.output) for r in reqs] == ref
    assert stats.preemptions > 0 and stats.resumes > 0
    assert stats.swapped_fixed_bytes > 0
    assert plan.injected["fixed_drain"] == 1
    eng.pager.check_invariants()


def test_whisper_engine_token_identical_with_enc_dedup_and_swap(whisper):
    """Enc-dec continuous batching: read-only encoder pages are deduplicated
    across requests with identical frames and survive a mid-decode
    preemption (detach under holds / reattach) token-identically."""
    cfg, params = whisper
    rng = np.random.default_rng(7)
    lens = (13, 9, 7, 12)
    elens = (6, 9, 11, 7)
    reqs = []
    for i in range(5):
        fr = (rng.standard_normal((elens[i % 4], cfg.d_model)) * 0.1
              ).astype(np.float32)
        if i == 1:
            fr = reqs[0].frames.copy()    # duplicate audio -> enc cache hit
        reqs.append(Request(uid=i,
                            prompt=rng.integers(2, cfg.vocab_size,
                                                lens[i % 4]).astype(np.int32),
                            max_tokens=6, frames=fr))
    ref = _ref_outputs(params, cfg, reqs, max_seq=20)

    eng = ServingEngine(params, cfg, batch_size=3, max_seq=20, page_size=4,
                        num_pages=1 + 14, backend="xla",
                        max_prefill_tokens=8)
    for r in reqs:
        eng.submit(r)
    # force a mid-decode preemption: the admission watermark keeps this pool
    # from exhausting naturally, so exercise the swap path white-box
    for _ in range(30):
        eng.step()
        dec = [i for i in eng._active_slots()
               if eng.pos[i] >= eng.pref_target[i]]
        if len(dec) >= 2:
            eng._preempt(dec[0])
            break
    stats = eng.run_until_drained(max_steps=600)
    assert [list(r.output) for r in reqs] == ref
    assert stats.preemptions > 0 and stats.resumes > 0
    assert stats.enc_hits >= 1
    assert stats.enc_encodes >= 1
    assert stats.swapped_fixed_bytes == 0   # no fixed-rows leaf on enc-dec
    eng.pager.check_invariants()


# ================================================== rejection / guards ==
def test_unsupported_mixer_raises_named_error_at_construction():
    """An unsupported mixer family fails engine *construction* with the
    named error (no mid-step AttributeError ever runs)."""
    cfg = get_config("rwkv6-7b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(UnsupportedModelError, match="paged serving"):
        ServingEngine(params, cfg, batch_size=2, max_seq=32)
    # ...and the named class still honors the historical contract
    assert issubclass(UnsupportedModelError, NotImplementedError)


def test_token_prefix_cache_rejected_on_stateful_leaves(zamba, whisper):
    for cfg, params in (zamba, whisper):
        with pytest.raises(ValueError, match="prefix"):
            ServingEngine(params, cfg, batch_size=2, max_seq=24,
                          backend="xla", prefix_cache=True)


def test_frames_validation_on_submit(zamba, whisper):
    cfg_w, params_w = whisper
    eng = ServingEngine(params_w, cfg_w, batch_size=2, max_seq=20,
                        backend="xla")
    with pytest.raises(RejectedRequest):        # enc-dec requires frames
        eng.submit(Request(uid=0, prompt=np.asarray([3, 4], np.int32),
                           max_tokens=2))
    with pytest.raises(RejectedRequest):        # wrong feature width
        eng.submit(Request(uid=1, prompt=np.asarray([3, 4], np.int32),
                           max_tokens=2,
                           frames=np.zeros((4, cfg_w.d_model + 1),
                                           np.float32)))
    cfg_z, params_z = zamba
    eng2 = ServingEngine(params_z, cfg_z, batch_size=2, max_seq=20,
                         backend="xla")
    with pytest.raises(RejectedRequest):        # frames on a decoder-only
        eng2.submit(Request(uid=2, prompt=np.asarray([3, 4], np.int32),
                            max_tokens=2,
                            frames=np.zeros((4, cfg_z.d_model), np.float32)))


# ==================================================== operator visibility ==
def test_pending_report_phases_and_deadlines(zamba):
    """The stuck-set report names each request's phase (queued / prefilling /
    decoding / swapped) and its remaining deadline, not just pager counts."""
    cfg, params = zamba
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=24, page_size=4,
                        backend="xla", max_prefill_tokens=4)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab_size, 9).astype(np.int32),
                    max_tokens=4, deadline_s=30.0 if i == 0 else None)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    rep = eng._pending_report()
    assert "phase=prefilling" in rep and "phase=queued" in rep
    line0 = next(l for l in rep.splitlines() if "uid=0" in l)
    assert "deadline=-" not in line0 and "deadline=" in line0  # 30s budget
    lineq = next(l for l in rep.splitlines() if "phase=queued" in l)
    assert "deadline=-" in lineq                # no deadline at all
    for _ in range(20):
        eng.step()
        dec = [i for i in eng._active_slots()
               if eng.pos[i] >= eng.pref_target[i]]
        if dec:
            break
    assert "phase=decoding" in eng._pending_report()
    eng._preempt(dec[0])
    assert "phase=swapped" in eng._pending_report()
    eng.run_until_drained(max_steps=600)


# ========================================================== fault sites ==
def test_enc_evict_fault_degrades_to_fresh_encode(whisper):
    """enc_evict forces the matched encoder page set out between match and
    attach: the duplicate-frames admission degrades to a fresh encode and
    serving still completes."""
    cfg, params = whisper
    plan = FaultPlan([FaultSpec("enc_evict", op=0, times=1)], seed=0)
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=20, page_size=4,
                        backend="xla", fault_plan=plan)
    rng = np.random.default_rng(11)
    fr = (rng.standard_normal((6, cfg.d_model)) * 0.1).astype(np.float32)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab_size, 7).astype(np.int32),
                    max_tokens=3, frames=fr.copy())
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained(max_steps=300)
    assert stats.completed == 2
    assert plan.injected["enc_evict"] == 1
    assert stats.enc_hits == 0                  # the hit was forced away
    assert stats.enc_encodes == 2
    eng.pager.check_invariants()
