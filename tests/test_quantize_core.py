"""Deterministic (hypothesis-free) coverage of the quantization core: the
pack/unpack group-split layout, the RTN quantize→dequantize error bound, the
fake-quantize consistency, and the Pallas W4A16 kernel in interpret mode vs
the XLA dequant reference.  Guards eq. 1 of the paper on a clean machine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as q
from repro.kernels import ops


def _rand_w(ci, co, seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (ci, co), jnp.float32) * scale


@pytest.mark.parametrize("ci,co,g", [(64, 32, 64), (128, 64, 128),
                                     (256, 32, 64), (256, 128, 128)])
def test_pack_unpack_group_split_roundtrip(ci, co, g):
    codes = jax.random.randint(jax.random.PRNGKey(1), (ci, co), 0, 16, jnp.uint8)
    packed = q.pack_codes(codes, g)
    assert packed.shape == (ci // 2, co) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(q.unpack_codes(packed, g), codes)


def test_pack_layout_is_group_split():
    """Within a group of G rows, packed row r holds code[g*G+r] in the low
    nibble and code[g*G+G/2+r] in the high nibble (the TPU-kernel contract)."""
    g = 8
    codes = (jnp.arange(16, dtype=jnp.uint8) % 16)[:, None]     # [16, 1], 2 groups
    packed = np.asarray(q.pack_codes(codes, g))
    for grp in range(2):
        for r in range(g // 2):
            lo = int(codes[grp * g + r, 0])
            hi = int(codes[grp * g + g // 2 + r, 0])
            assert packed[grp * (g // 2) + r, 0] == (lo | (hi << 4))


@pytest.mark.parametrize("g", [32, 64, 128])
def test_quant_dequant_error_bounded_by_half_step(g):
    w = _rand_w(256, 64)
    w_hat = q.dequantize(q.quantize(w, group_size=g), jnp.float32)
    wf = np.asarray(w).reshape(256 // g, g, 64)
    step = (wf.max(1) - wf.min(1)) / 15.0
    err = np.abs(np.asarray(w_hat).reshape(256 // g, g, 64) - wf)
    assert (err <= step[:, None, :] * 0.5 + 1e-6).all()


def test_dequant_quant_matches_fake_quantize():
    w = _rand_w(256, 48, seed=3)
    via_qt = q.dequantize(q.quantize(w, group_size=64), jnp.float32)
    np.testing.assert_allclose(np.asarray(via_qt),
                               np.asarray(q.fake_quantize(w, 64)),
                               rtol=0, atol=1e-6)


def test_constant_groups_use_scale_fallback():
    # constant group → zero range → scale falls back to 1; the round-trip
    # then reduces to round(), i.e. error ≤ 0.5 instead of NaN/inf
    w = jnp.full((64, 8), 0.37, jnp.float32)
    w_hat = np.asarray(q.dequantize(q.quantize(w, 64), jnp.float32))
    assert np.isfinite(w_hat).all()
    assert (np.abs(w_hat - 0.37) <= 0.5).all()
    # all-zero weights survive exactly
    z = jnp.zeros((64, 8), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(q.dequantize(q.quantize(z, 64), jnp.float32)), 0.0)


def test_quantized_tensor_metadata():
    w = _rand_w(256, 64)
    qt = q.quantize(w, group_size=64)
    assert qt.shape == (256, 64)
    assert qt.group_size == 64
    # int4 + per-group f32 scales/zeros ≈ 8x smaller than f32
    assert qt.nbytes_quant() < w.size * 4 / 4


@pytest.mark.parametrize("t,ci,co,g", [(8, 128, 128, 64), (16, 128, 256, 128)])
def test_w4a16_interpret_matches_xla_reference(t, ci, co, g):
    """Pallas kernel body (interpret mode, CPU) vs the XLA dequant-matmul."""
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (t, ci), jnp.float32)
    qt = q.quantize(jax.random.normal(kw, (ci, co), jnp.float32), group_size=g)
    ref = ops.w4a16_matmul(x, qt, backend="xla")
    got = ops.w4a16_matmul(x, qt, backend="interpret", block_t=8, block_co=co)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_w4a16_xla_equals_explicit_dequant_matmul():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 128), jnp.float32)
    qt = q.quantize(_rand_w(128, 64, seed=5), group_size=64)
    ref = x @ q.dequantize(qt, jnp.float32)
    got = ops.w4a16_matmul(x, qt, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
