"""Pallas paged-attention decode kernel (interpret mode) vs the jnp
dense-gather reference: GQA and MLA, fp16 and int8 pools, ragged lengths,
partial last pages, batch > 1; plus the int8-pool engine end-to-end and the
stale-page-table guard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models import attention as A
from repro.serving import kv_cache as KV
from repro.serving.engine import Request, ServingEngine

ATOL = 1e-2  # bf16 activations; fp32 checks below are much tighter in practice


def _paged_state(batch, pages_per_slot, page_size, seed=0):
    """Pager + table with every slot allocated, trash page garbage included."""
    pool_host = KV.PagePool(1 + batch * pages_per_slot, page_size, batch,
                            pages_per_slot)
    for s in range(batch):
        pool_host.alloc(s, pages_per_slot)
    return pool_host, jnp.asarray(pool_host.table())


def _fill(pool, seed):
    """Random pool contents (all pages, including trash-page garbage)."""
    out = {}
    for i, (k, v) in enumerate(sorted(pool.items())):
        kk = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        if v.dtype == jnp.int8:
            out[k] = jax.random.randint(kk, v.shape, -127, 128, jnp.int8)
        elif k.endswith("_s"):
            out[k] = jax.random.uniform(kk, v.shape, jnp.float32, 1e-3, 2e-2)
        else:
            out[k] = jax.random.normal(kk, v.shape, jnp.float32).astype(v.dtype)
    return out


# ragged: mid-page, page boundary - 1, full table - 1 (partial/full last page)
WRITE_POS = [4, 15, 23]


@pytest.mark.parametrize("kv_quant", [False, True])
def test_gqa_paged_kernel_matches_gather(kv_quant):
    cfg = get_config("codellama-7b", smoke=True).with_(kv_quant=kv_quant)
    b, ps, pages = len(WRITE_POS), 8, 3
    p = A.init_gqa(jax.random.PRNGKey(0), cfg)
    _, table = _paged_state(b, pages, ps)
    pool = _fill(A.init_gqa_page_pool(cfg, 1 + b * pages, ps), seed=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (b, 1, cfg.d_model), cfg.jdtype)
    wp = jnp.asarray(WRITE_POS)
    y_ref, pool_ref = A.gqa_decode_paged(
        p, x, wp[:, None], pool, table, wp,
        cfg.with_(paged_attn_impl="gather"), backend="xla")
    y_ker, pool_ker = A.gqa_decode_paged(
        p, x, wp[:, None], pool, table, wp,
        cfg.with_(paged_attn_impl="pallas_interpret"), backend="xla")
    np.testing.assert_allclose(
        np.asarray(y_ker, np.float32), np.asarray(y_ref, np.float32),
        atol=ATOL, rtol=ATOL)
    # the token write path is shared: updated pools must be identical
    for key in pool_ref:
        np.testing.assert_array_equal(np.asarray(pool_ref[key]),
                                      np.asarray(pool_ker[key]))


@pytest.mark.parametrize("kv_quant", [False, True])
def test_mla_paged_kernel_matches_gather(kv_quant):
    cfg = get_config("deepseek-v2-236b", smoke=True).with_(kv_quant=kv_quant)
    b, ps, pages = len(WRITE_POS), 8, 3
    p = A.init_mla(jax.random.PRNGKey(0), cfg)
    _, table = _paged_state(b, pages, ps)
    pool = _fill(A.init_mla_page_pool(cfg, 1 + b * pages, ps), seed=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (b, 1, cfg.d_model), cfg.jdtype)
    wp = jnp.asarray(WRITE_POS)
    y_ref, _ = A.mla_decode_paged(
        p, x, wp[:, None], pool, table, wp,
        cfg.with_(paged_attn_impl="gather"), backend="xla")
    y_ker, _ = A.mla_decode_paged(
        p, x, wp[:, None], pool, table, wp,
        cfg.with_(paged_attn_impl="pallas_interpret"), backend="xla")
    np.testing.assert_allclose(
        np.asarray(y_ker, np.float32), np.asarray(y_ref, np.float32),
        atol=ATOL, rtol=ATOL)


def test_gqa_kernel_ignores_trash_page_garbage():
    """Rows past each sequence's length live on dead/trash pages; poisoning
    them with huge values must not leak into the kernel output."""
    cfg = get_config("codellama-7b", smoke=True)
    b, ps, pages = len(WRITE_POS), 8, 3
    p = A.init_gqa(jax.random.PRNGKey(0), cfg)
    _, table = _paged_state(b, pages, ps)
    pool = _fill(A.init_gqa_page_pool(cfg, 1 + b * pages, ps), seed=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (b, 1, cfg.d_model), cfg.jdtype)
    wp = jnp.asarray(WRITE_POS)
    impl = cfg.with_(paged_attn_impl="pallas_interpret")
    y0, _ = A.gqa_decode_paged(p, x, wp[:, None], pool, table, wp, impl,
                               backend="xla")
    poisoned = dict(pool, k=pool["k"].at[KV.TRASH_PAGE].set(1e4),
                    v=pool["v"].at[KV.TRASH_PAGE].set(1e4))
    y1, _ = A.gqa_decode_paged(p, x, wp[:, None], poisoned, table, wp, impl,
                               backend="xla")
    np.testing.assert_array_equal(np.asarray(y0, np.float32),
                                  np.asarray(y1, np.float32))


def test_int8_pool_shapes_and_prefix_quantization():
    """init_paged_cache allocates int8 + scale pools under kv_quant, and
    quantize_raw_paged produces a matching tree that round-trips ~exactly."""
    cfg = get_config("codellama-7b", smoke=True).with_(kv_quant=True)
    pools = api.init_paged_cache(cfg, num_pages=5, page_size=4)
    lay = pools["layers"]
    assert lay["k"].dtype == jnp.int8 and lay["v"].dtype == jnp.int8
    assert lay["k_s"].dtype == jnp.float32
    assert lay["k_s"].shape == lay["k"].shape[:-1]
    raw = {"layers": {
        "k": jax.random.normal(jax.random.PRNGKey(0), (2, 1, 4, 2, 8)),
        "v": jax.random.normal(jax.random.PRNGKey(1), (2, 1, 4, 2, 8)),
    }}
    q = api.quantize_raw_paged(raw, cfg)["layers"]
    assert set(q) == {"k", "k_s", "v", "v_s"}
    deq = q["k"].astype(jnp.float32) * q["k_s"][..., None]
    np.testing.assert_allclose(np.asarray(deq), np.asarray(raw["layers"]["k"]),
                               atol=2e-2)
    # tree structure matches the pools → write_prefix scatters leaf-for-leaf
    assert set(q) == set(lay)


def _greedy_ref(params, cfg, prompt, max_tokens, smax, eos=1):
    logits, cache = api.prefill_fn(
        params, {"tokens": jnp.asarray(prompt)[None]}, cfg, smax, backend="xla")
    out = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    while len(out) < max_tokens and out[-1] != eos and pos < smax - 1:
        lg, cache = api.decode_fn(
            params, {"token": jnp.asarray([[out[-1]]], jnp.int32),
                     "position": jnp.asarray([pos], jnp.int32)},
            cache, cfg, backend="xla")
        out.append(int(jnp.argmax(lg, -1)[0]))
        pos += 1
    return out


def test_engine_kv_quant_greedy_token_identical_across_impls():
    """int8 KV paged serving end to end: the engine no longer raises under
    kv_quant, and the Pallas kernel path emits token-identical output to the
    jnp gather path over a mixed-length continuous-batching run."""
    cfg = get_config("codellama-7b", smoke=True).with_(kv_quant=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)

    def run(impl):
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            size=(5, 9, 7, 12)[i % 4]
                                            ).astype(np.int32),
                        max_tokens=5)
                for i in range(5)]
        eng = ServingEngine(params, cfg.with_(paged_attn_impl=impl),
                            batch_size=3, max_seq=32, page_size=8,
                            backend="xla")
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert stats.completed == len(reqs)
        eng.pager.check_invariants()
        return [r.output for r in reqs]

    assert run("gather") == run("pallas_interpret")


def test_engine_fp16_kernel_impl_matches_monolithic_greedy():
    """Non-quantized engine on the kernel path stays token-identical to the
    contiguous-cache greedy reference (the PR-1 acceptance bar)."""
    cfg = get_config("codellama-7b", smoke=True).with_(
        paged_attn_impl="pallas_interpret")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        size=(5, 9)[i % 2]).astype(np.int32),
                    max_tokens=5)
            for i in range(3)]
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=32, page_size=8,
                        backend="xla")
    for r in reqs:
        eng.submit(r)
    assert eng.run_until_drained().completed == 3
    base = cfg.with_(paged_attn_impl="auto")
    for r in reqs:
        assert r.output == _greedy_ref(params, base, r.prompt, r.max_tokens, 32)


# ------------------------------------------------------- stale-table guard --
def test_stale_table_guard_raises_on_freed_active_slot():
    pool = KV.PagePool(num_pages=9, page_size=4, batch_size=2,
                       max_pages_per_slot=4)
    pool.alloc(0, 2)
    write_pos = np.array([5, 0], np.int32)
    active = [True, False]
    KV.assert_live_tables(pool.table(), write_pos, 4, active)   # fine
    pool.free_slot(0)                                            # use-after-free
    with pytest.raises(RuntimeError, match="stale page table"):
        KV.assert_live_tables(pool.table(), write_pos, 4, active)
    # idle slots pointing at trash are fine
    KV.assert_live_tables(pool.table(), write_pos, 4, [False, False])


@pytest.mark.slow
def test_gqa_paged_kernel_compiles_on_tpu():
    """Real-TPU compile/execute smoke (skipped on CPU CI; `-m slow` on TPU)."""
    if jax.default_backend() != "tpu":
        pytest.skip("needs a real TPU")
    cfg = get_config("codellama-7b", smoke=True)
    b, ps, pages = 2, 16, 2
    p = A.init_gqa(jax.random.PRNGKey(0), cfg)
    _, table = _paged_state(b, pages, ps)
    pool = _fill(A.init_gqa_page_pool(cfg, 1 + b * pages, ps), seed=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (b, 1, cfg.d_model), cfg.jdtype)
    wp = jnp.asarray([3, 17])
    y, _ = A.gqa_decode_paged(p, x, wp[:, None], pool, table, wp,
                              cfg.with_(paged_attn_impl="pallas"),
                              backend="pallas")
    assert np.isfinite(np.asarray(y, np.float32)).all()
