"""PTQ artifact (quantize-once / serve-many): npz round trip with uint8
packed leaves intact, config-hash staleness guard, and engine boot from the
artifact with ZERO calibration batches + zero α-search steps producing
greedy tokens identical to quantize-on-load."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import QuantConfig
from repro.core import apply as AP
from repro.core import calibration as C
from repro.core.quantize import QuantizedTensor
from repro.models import api
from repro.serving.engine import Request, ServingEngine, load_or_quantize


@pytest.fixture(scope="module")
def quantized(tmp_path_factory):
    cfg = get_config("deepseek-v2-236b", smoke=True).with_(dtype="float32")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    batches = C.synthetic_calibration_set(cfg, n_seqs=1, seq_len=12)
    qcfg = QuantConfig(group_size=16)
    art = tmp_path_factory.mktemp("ptq") / "artifact"
    qp, rep = load_or_quantize(params, cfg, batches, qcfg,
                               artifact_dir=art)
    return cfg, qcfg, qp, rep, art


def _poison_calibration():
    """Iterable that fails the test if the engine boot consumes ANY batch."""
    raise AssertionError("artifact boot ran calibration")
    yield  # pragma: no cover


def test_artifact_round_trip_bit_exact(quantized):
    cfg, qcfg, qp, rep, art = quantized
    qp2, rep2 = AP.load_ptq(art, cfg, qcfg)
    flat1 = jax.tree_util.tree_flatten_with_path(qp)[0]
    flat2 = {jax.tree_util.keystr(p): l
             for p, l in jax.tree_util.tree_flatten_with_path(qp2)[0]}
    assert len(flat1) == len(flat2)
    for path, leaf in flat1:
        other = flat2[jax.tree_util.keystr(path)]
        assert leaf.dtype == other.dtype, path
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(other))
    # packed int4 codes survive as uint8, scales keep their dtype
    mixer0 = qp2["layers"]["mixer"]
    assert isinstance(mixer0["wkv_b"]["w"], QuantizedTensor)
    assert mixer0["wkv_b"]["w"].packed.dtype == jnp.uint8
    assert isinstance(mixer0["wkv_b_absorbed"]["wk_t"], QuantizedTensor)
    # report rides along
    assert rep2.alpha == rep.alpha
    assert rep2.loss_curve == rep.loss_curve
    assert rep2.quantized_paths == [tuple(map(str, p))
                                    for p in rep.quantized_paths]


def test_artifact_boot_zero_calibration_greedy_identical(quantized):
    """Acceptance: engine boot from the artifact runs zero calibration
    batches / zero α-search steps and serves token-identical output."""
    cfg, qcfg, qp, _, art = quantized
    qp2, _ = load_or_quantize(None, cfg, _poison_calibration(), qcfg,
                              artifact_dir=art)

    def greedy(p):
        rng = np.random.default_rng(0)
        eng = ServingEngine(p, cfg, batch_size=2, max_seq=32, backend="xla")
        reqs = [Request(uid=i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            size=(5, 8)[i % 2]).astype(np.int32),
                        max_tokens=4) for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        return [r.output for r in reqs]

    assert greedy(qp2) == greedy(qp)


def test_stale_artifact_rejected_and_requantized(quantized, tmp_path):
    cfg, qcfg, qp, _, art = quantized
    other = dataclasses.replace(qcfg, alpha=0.5)
    with pytest.raises(AP.StalePTQArtifactError):
        AP.load_ptq(art, cfg, other)
    # load_or_quantize falls back to a fresh quantization run
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    batches = C.synthetic_calibration_set(cfg, n_seqs=1, seq_len=12)
    qp3, _ = load_or_quantize(params, cfg, batches, other,
                              artifact_dir=tmp_path / "art2")
    assert AP.has_ptq(tmp_path / "art2")


def test_fingerprint_sensitive_to_configs():
    cfg = get_config("deepseek-v2-236b", smoke=True)
    q16, q32 = QuantConfig(group_size=16), QuantConfig(group_size=32)
    assert AP.ptq_fingerprint(cfg, q16) != AP.ptq_fingerprint(cfg, q32)
    assert AP.ptq_fingerprint(cfg, q16) != AP.ptq_fingerprint(
        cfg.with_(dtype="float32"), q16)
    assert AP.ptq_fingerprint(cfg, q16) == AP.ptq_fingerprint(cfg, q16)


@pytest.mark.parametrize("victim,garbage", [
    ("meta.json", b"{ truncated"),
    ("arrays.npz", b"not a zip at all"),          # BadZipFile path
])
def test_corrupt_artifact_falls_back_to_requantize(quantized, tmp_path,
                                                   victim, garbage):
    """A truncated/corrupt artifact (either file) must not crash boot:
    load_or_quantize re-runs the recipe and re-saves a valid artifact."""
    cfg, qcfg, qp, rep, _ = quantized
    art = tmp_path / "corrupt"
    AP.save_ptq(art, qp, rep, cfg, qcfg)
    (art / victim).write_bytes(garbage)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    batches = C.synthetic_calibration_set(cfg, n_seqs=1, seq_len=12)
    qp2, _ = load_or_quantize(params, cfg, batches, qcfg, artifact_dir=art)
    AP.load_ptq(art, cfg, qcfg)                   # valid again


def test_artifact_save_is_atomic(quantized, tmp_path):
    """A half-written tmp dir is never visible as an artifact."""
    cfg, qcfg, qp, rep, _ = quantized
    target = tmp_path / "atomic"
    AP.save_ptq(target, qp, rep, cfg, qcfg)
    assert AP.has_ptq(target)
    assert not (tmp_path / "atomic.tmp").exists()
    # overwrite in place keeps a loadable artifact
    AP.save_ptq(target, qp, rep, cfg, qcfg)
    AP.load_ptq(target, cfg, qcfg)
