"""Unit + property tests for group-wise int4 RTN quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quantize as q


def _rand_w(ci, co, seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (ci, co), jnp.float32) * scale


@pytest.mark.parametrize("ci,co,g", [(128, 64, 128), (256, 128, 128), (256, 32, 64), (512, 256, 128)])
def test_pack_unpack_roundtrip(ci, co, g):
    k = jax.random.PRNGKey(1)
    codes = jax.random.randint(k, (ci, co), 0, 16, jnp.uint8)
    packed = q.pack_codes(codes, g)
    assert packed.shape == (ci // 2, co)
    assert packed.dtype == jnp.uint8
    np.testing.assert_array_equal(q.unpack_codes(packed, g), codes)


@pytest.mark.parametrize("g", [64, 128])
def test_quant_dequant_error_bound(g):
    w = _rand_w(256, 128)
    qt = q.quantize(w, group_size=g)
    w_hat = q.dequantize(qt, jnp.float32)
    # error per element bounded by step/2; step = (max-min)/15 per group/outchan
    wf = np.asarray(w).reshape(256 // g, g, 128)
    step = (wf.max(1) - wf.min(1)) / 15.0
    err = np.abs(np.asarray(w_hat).reshape(256 // g, g, 128) - wf)
    assert (err <= step[:, None, :] * 0.5 + 1e-6).all()


def test_fake_quantize_matches_quant_dequant():
    w = _rand_w(256, 64, seed=3)
    qt = q.quantize(w, group_size=128)
    np.testing.assert_allclose(
        np.asarray(q.dequantize(qt, jnp.float32)),
        np.asarray(q.fake_quantize(w, 128)),
        rtol=0, atol=1e-5,
    )


def test_constant_group_is_exactly_representable():
    w = jnp.full((128, 16), 0.37, jnp.float32)
    qt = q.quantize(w)
    # scale forced to 1, zero=round(-0.37)=0 → codes=round(0.37)=0 → dequant 0?
    # Constant groups have max==min; we just require finite output and zero
    # *relative spread*, and that adding any spread makes it near-exact.
    w2 = w.at[0, :].set(0.38)
    got = q.dequantize(q.quantize(w2), jnp.float32)
    assert jnp.isfinite(got).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(w2), atol=0.001)


def test_quantization_loss_weights_outlier_channels():
    w = _rand_w(256, 64, seed=5)
    x_flat = jnp.ones((256,))
    x_out = x_flat.at[7].set(100.0)
    assert float(q.quantization_loss(w, x_out)) > float(q.quantization_loss(w, x_flat))


@settings(max_examples=25, deadline=None)
@given(
    ci_groups=st.integers(1, 4),
    co=st.sampled_from([8, 32, 128]),
    g=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_property_quant_error_half_step(ci_groups, co, g, seed, scale):
    """Property: |W - Ŵ| <= Δ/2 elementwise (clamp never binds for in-range data)."""
    ci = ci_groups * g
    w = np.asarray(_rand_w(ci, co, seed=seed, scale=scale))
    qt = q.quantize(jnp.asarray(w), group_size=g)
    w_hat = np.asarray(q.dequantize(qt, jnp.float32))
    wf = w.reshape(ci_groups, g, co)
    step = (wf.max(1) - wf.min(1)) / 15.0
    step = np.where(step <= 0, 1.0, step)
    err = np.abs(w_hat.reshape(ci_groups, g, co) - wf)
    # zero-point rounding adds at most another half step of shift
    assert (err <= step[:, None, :] * 1.0 + 1e-5 * scale).all()


def test_quantized_tensor_memory_footprint():
    w = _rand_w(4096, 4096)
    qt = q.quantize(w, group_size=128)
    fp16_bytes = w.size * 2
    ratio = qt.nbytes_quant() / fp16_bytes
    assert ratio < 0.30  # ~0.25 + scales/zeros overhead
