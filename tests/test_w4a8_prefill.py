"""W4A8 prefill path: A8 kernel bodies vs XLA oracles, dispatch gating,
calibrated eligibility, artifact round trip, and model-level closeness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import QuantConfig
from repro.core import apply as AP
from repro.core import calibration as C
from repro.core import smoothing as SM
from repro.core import quantize as q
from repro.kernels import ops
from repro.kernels.ref import w4a8_matmul_ref, w4a8_grouped_ref, w4a16_matmul_ref
from repro.kernels.w4a16_matmul import w4a16_matmul
from repro.kernels.w4a16_grouped import w4a16_grouped_matmul
from repro.models import api


def _mk(t, ci, co, g, seed=0, dtype=jnp.float32):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (t, ci), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (ci, co), jnp.float32)
    return x, q.quantize(w, group_size=g)


# --------------------------------------------------- kernel parity (ragged) --
@pytest.mark.parametrize(
    "t,ci,co,g",
    [
        (16, 128, 128, 128),   # minimal A8-gated shape, aligned
        (64, 256, 512, 64),    # multi-block, non-default group
        (300, 384, 384, 128),  # T and Co not multiples of default blocks
        (33, 96, 112, 16),     # everything ragged, tiny groups
        (17, 48, 40, 48),      # Ci one group, Co forces block shrink
    ],
)
@pytest.mark.parametrize("act", ["a16", "a8"])
def test_kernel_ragged_parity(t, ci, co, g, act):
    """Interpret-mode kernel vs XLA oracle for BOTH bodies at ragged shapes
    (T, Co, Ci off the default blocks; non-default group sizes)."""
    x, qt = _mk(t, ci, co, g)
    got = w4a16_matmul(x, qt, block_t=128, block_co=128, interpret=True,
                       act=act)
    ref = w4a8_matmul_ref if act == "a8" else w4a16_matmul_ref
    want = ref(x, qt)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize(
    "e,c,d,f,g",
    [
        (2, 16, 64, 64, 16),
        (4, 37, 64, 80, 16),   # ragged capacity AND ragged Co
        (3, 21, 96, 48, 48),
    ],
)
@pytest.mark.parametrize("act", ["a16", "a8"])
def test_grouped_kernel_ragged_parity(e, c, d, f, g, act):
    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (e, c, d), jnp.float32)
    w = jax.random.normal(kw, (e, d, f), jnp.float32)
    qt = q.quantize(w, group_size=g)
    got = w4a16_grouped_matmul(x, qt, interpret=True, act=act)
    from repro.kernels.ref import w4a16_grouped_ref
    ref = w4a8_grouped_ref if act == "a8" else w4a16_grouped_ref
    want = ref(x, qt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_a8_oracle_is_exact_int8_math():
    """The XLA oracle's f32 einsum must equal true int32 integer math (all
    products/group-sums sit below 2^24) — the parity target is exact."""
    x, qt = _mk(32, 128, 64, 16, seed=5)
    xq, xs = q.quantize_acts_per_token(x)
    from repro.kernels.ref import _folded_int_codes
    wq = _folded_int_codes(qt)                      # [g, ci/g, co] f32 codes
    g = wq.shape[-3]
    xg = xq.astype(jnp.int32).reshape(32, g, -1)
    part = jnp.einsum("tgi,gio->tgo", xg, wq.astype(jnp.int32))
    y_int = (jnp.sum(part.astype(jnp.float32)
                     * qt.scales.astype(jnp.float32)[None], axis=1) * xs)
    np.testing.assert_array_equal(np.asarray(y_int, np.float32),
                                  np.asarray(w4a8_matmul_ref(x, qt), np.float32))


# ------------------------------------------------------------ ops gating ----
def test_small_t_request_falls_back_bit_identical():
    """Below ops.A8_MIN_TOKENS rows, an act="a8" request must return the
    bit-identical A16 result (decode stays on the memory-bound A16 body)."""
    x, qt = _mk(ops.A8_MIN_TOKENS - 1, 128, 128, 128, seed=2)
    a16 = ops.w4a16_matmul(x, qt, backend="xla")
    a8 = ops.w4a16_matmul(x, qt, backend="xla", act="a8")
    np.testing.assert_array_equal(np.asarray(a16), np.asarray(a8))


def test_ineligible_flag_falls_back_bit_identical():
    x, qt = _mk(64, 128, 128, 128, seed=3)
    qt_off = dataclasses.replace(qt, a8=False)
    a16 = ops.w4a16_matmul(x, qt, backend="xla")
    a8 = ops.w4a16_matmul(x, qt_off, backend="xla", act="a8")
    np.testing.assert_array_equal(np.asarray(a16), np.asarray(a8))


def test_a8_dispatch_xla_equals_interpret():
    x, qt = _mk(32, 256, 128, 128, seed=4)
    a = ops.w4a16_matmul(x, qt, backend="xla", act="a8")
    b = ops.w4a16_matmul(x, qt, backend="interpret", act="a8",
                         block_t=32, block_co=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-4)
    assert not np.array_equal(
        np.asarray(a), np.asarray(ops.w4a16_matmul(x, qt, backend="xla")))


def test_bad_act_rejected():
    x, qt = _mk(16, 128, 128, 128)
    with pytest.raises(ValueError, match="act"):
        ops.w4a16_matmul(x, qt, backend="xla", act="a4")


def test_a8_flag_is_static_metadata():
    """a8 rides tree metadata, not a traced leaf: jit must retrace on flip
    (kernel choice is trace-time) and tree_map must preserve the flag."""
    _, qt = _mk(16, 128, 128, 128)
    qt_off = dataclasses.replace(qt, a8=False)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    leaves_off, treedef_off = jax.tree_util.tree_flatten(qt_off)
    assert len(leaves) == len(leaves_off) == 3
    assert treedef != treedef_off
    assert jax.tree_util.tree_map(lambda a: a, qt_off).a8 is False


# ------------------------------------------- eligibility + artifact flags ----
@pytest.fixture(scope="module")
def outlier_ptq():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.common import outlier_model

    cfg, params = outlier_model("codellama-7b")
    batches = C.synthetic_calibration_set(cfg, n_seqs=2, seq_len=24)
    qcfg = QuantConfig(group_size=16, alpha=0.5)
    qp, rep = AP.smoothquant_plus(params, cfg, batches, qcfg)
    return cfg, qcfg, params, qp, rep


def test_eligibility_map_mixed(outlier_ptq):
    """The injected hot channels must push at least one layer back to A16
    while well-behaved layers stay A8-eligible — and the tree flags must
    agree with the report."""
    cfg, qcfg, _, qp, rep = outlier_ptq
    flags = rep.a8_eligibility
    assert any(flags.values()), flags
    assert not all(flags.values()), flags
    for p in rep.quantized_paths:
        node = SM.tget(qp, p)
        key = "/".join(map(str, p))
        if isinstance(node, q.QuantizedTensor):
            assert node.a8 == flags[key]
    # every decided path has its deciding error recorded, and the decision
    # is exactly the threshold comparison
    for key, ok in flags.items():
        if key in rep.a8_errors:
            assert ok == (rep.a8_errors[key] <= qcfg.a8_threshold)


def test_artifact_roundtrip_preserves_flags(tmp_path, outlier_ptq):
    cfg, qcfg, _, qp, rep = outlier_ptq
    art = tmp_path / "a8art"
    AP.save_ptq(art, qp, rep, cfg, qcfg)
    tree2, rep2 = AP.load_ptq(art, cfg, qcfg)
    assert rep2.a8_eligibility == rep.a8_eligibility
    assert rep2.a8_errors == pytest.approx(rep.a8_errors)
    for p in rep.quantized_paths:
        n1, n2 = SM.tget(qp, p), SM.tget(tree2, p)
        if isinstance(n1, q.QuantizedTensor):
            assert n1.a8 == n2.a8, p
        else:
            assert all(n1[k].a8 == n2[k].a8 for k in n1), p


def test_threshold_change_invalidates_artifact(tmp_path, outlier_ptq):
    cfg, qcfg, _, qp, rep = outlier_ptq
    art = tmp_path / "a8stale"
    AP.save_ptq(art, qp, rep, cfg, qcfg)
    stale = dataclasses.replace(qcfg, a8_threshold=0.02)
    with pytest.raises(AP.StalePTQArtifactError):
        AP.load_ptq(art, cfg, stale)


def test_fingerprint_ignores_act_quant(outlier_ptq):
    """act_quant is a serving-time routing choice: one artifact must serve
    both A16 and A8-prefill engines without re-quantizing."""
    cfg, qcfg, *_ = outlier_ptq
    assert (AP.ptq_fingerprint(cfg, qcfg)
            == AP.ptq_fingerprint(cfg.with_(act_quant="a8_prefill"), qcfg))


# ------------------------------------------------------------- model level ---
def test_a8_prefill_logits_close_and_decode_untouched(outlier_ptq):
    cfg, qcfg, _, qp, rep = outlier_ptq
    a8cfg = cfg.with_(act_quant="a8_prefill")
    batch = C.synthetic_calibration_set(cfg, n_seqs=1, seq_len=32, seed=11)[0]
    l16 = np.asarray(api.forward_fn(qp, batch, cfg, backend="xla"), np.float32)
    l8 = np.asarray(api.forward_fn(qp, batch, a8cfg, backend="xla"), np.float32)
    rel = np.linalg.norm(l8 - l16) / np.linalg.norm(l16)
    n_elig = sum(v for k, v in rep.a8_eligibility.items()
                 if not k.endswith("wkv_b_absorbed"))
    assert 0 < rel <= qcfg.a8_threshold * n_elig * cfg.num_layers, rel
    # a 1-token forward sits under the token gate on every layer: A8 config
    # must produce bit-identical logits (the decode path is untouched)
    tiny = {"tokens": batch["tokens"][:, :1]}
    t16 = np.asarray(api.forward_fn(qp, tiny, cfg, backend="xla"))
    t8 = np.asarray(api.forward_fn(qp, tiny, a8cfg, backend="xla"))
    np.testing.assert_array_equal(t16, t8)


def test_engine_serves_a8_prefill_and_validates_act_quant(outlier_ptq):
    from repro.serving.engine import Request, ServingEngine

    cfg, qcfg, _, qp, _ = outlier_ptq
    with pytest.raises(ValueError, match="act_quant"):
        ServingEngine(qp, cfg.with_(act_quant="a8"), backend="xla")
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, 40).astype(np.int32)

    def drain(c):
        eng = ServingEngine(qp, c, batch_size=2, max_seq=56, page_size=8,
                            backend="xla", max_prefill_tokens=16)
        r = Request(uid=0, prompt=prompt.copy(), max_tokens=4)
        eng.submit(r)
        eng.run_until_drained()
        assert r.finish_reason in ("completed", "length")
        return r.output

    out16 = drain(cfg)
    out8 = drain(cfg.with_(act_quant="a8_prefill"))
    assert len(out16) == len(out8)  # equal outputs at equal budgets
