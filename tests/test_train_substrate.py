"""Optimizer, trainer, data pipeline, checkpoint manager, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import api
from repro.optim import adamw
from repro.train.trainer import make_train_step


@pytest.fixture(scope="module")
def small():
    cfg = get_config("codellama-7b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _data(cfg, batch=4, seq=32):
    return SyntheticTokens(DataConfig(
        seed=1, vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch))


def test_loss_decreases_over_steps(small):
    cfg, params = small
    tc = TrainConfig(learning_rate=3e-3, total_steps=30, warmup_steps=3)
    step = jax.jit(make_train_step(cfg, tc, backend="xla"))
    opt = adamw.init_opt_state(params, tc)
    data = _data(cfg)
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_microbatched_matches_full_batch_grads(small):
    cfg, params = small
    tc_full = TrainConfig(learning_rate=1e-3, microbatch=None)
    tc_micro = TrainConfig(learning_rate=1e-3, microbatch=2)
    data = _data(cfg)
    b = data.batch_at(0)
    p1, _, m1 = jax.jit(make_train_step(cfg, tc_full, "xla"))(
        params, adamw.init_opt_state(params, tc_full), b)
    p2, _, m2 = jax.jit(make_train_step(cfg, tc_micro, "xla"))(
        params, adamw.init_opt_state(params, tc_micro), b)
    # same data, averaged grads → parameters should match closely
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-2


def test_grad_compression_error_feedback(small):
    cfg, params = small
    tc = TrainConfig(learning_rate=3e-3, grad_compression="int8_ef",
                     total_steps=20, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, tc, backend="xla"))
    opt = adamw.init_opt_state(params, tc)
    assert opt.ef is not None
    data = _data(cfg)
    losses = []
    for i in range(20):
        params, opt, m = step(params, opt, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-4:]) < np.mean(losses[:4])  # still trains


def test_compress_int8_roundtrip_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3
    q, amax = adamw.compress_int8(g)
    d = adamw.decompress_int8(q, amax)
    assert float(jnp.max(jnp.abs(d - g))) <= float(amax) / 127 + 1e-6


def test_lr_schedule_shape():
    tc = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.lr_schedule(tc, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] < 0.2 and max(lrs) <= 1.0 and lrs[-1] < lrs[2]


# ------------------------------------------------------------- data pipe ----
def test_data_pipeline_deterministic_and_resumable():
    dc = DataConfig(seed=7, vocab_size=1000, seq_len=64, global_batch=4)
    d1, d2 = SyntheticTokens(dc), SyntheticTokens(dc)
    b1 = d1.batch_at(123)
    b2 = d2.batch_at(123)       # fresh instance, same step → identical
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = d1.iterate(start_step=5)
    np.testing.assert_array_equal(next(it)["tokens"], d2.batch_at(5)["tokens"])


def test_data_pipeline_labels_shifted():
    dc = DataConfig(seed=0, vocab_size=50, seq_len=16, global_batch=2)
    b = SyntheticTokens(dc).batch_at(0)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)


# ------------------------------------------------------------ checkpoints ---
def test_checkpoint_atomic_roundtrip(tmp_path, small):
    cfg, params = small
    tc = TrainConfig()
    opt = adamw.init_opt_state(params, tc)
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(10, (params, opt), {"loss": 1.0})
    mgr.save(20, (params, opt), {"loss": 0.5})
    mgr.save(30, (params, opt), {"loss": 0.4})
    assert mgr.all_steps() == [20, 30]  # retention
    (p2, o2), meta = mgr.restore((params, opt))
    assert meta["step"] == 30
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_ignores_partial_writes(tmp_path, small):
    cfg, params = small
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, params)
    # simulate a crash mid-write: tmp dir left behind
    bad = tmp_path / "step_00000009.tmp"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    # and a directory without meta (incomplete rename target — not possible
    # with atomic rename, but be paranoid)
    half = tmp_path / "step_00000007"
    half.mkdir()
    assert mgr.latest_step() == 5
    _, meta = mgr.restore(params)
    assert meta["step"] == 5


def test_checkpoint_shape_mismatch_raises(tmp_path, small):
    cfg, params = small
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((8, 8))})


def test_elastic_restore_re_layout(tmp_path, small):
    """Restore with explicit shardings (elastic re-mesh path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg, params = small
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.arange(16.0).reshape(4, 4)})
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P())}
    tree, _ = mgr.restore({"w": jnp.zeros((4, 4))}, shardings=sh)
    assert tree["w"].sharding == sh["w"]
