"""Observability subsystem: metrics core percentile math (hand-computed
against the documented rule), snapshot-delta percentiles, engine latency
histograms under a fake clock (TTFT/ITL/e2e vs hand-derived values),
bounded trace recording, golden Chrome trace export, the pending-report
fold into ``metrics_snapshot``, fault-counter wiring, swap byte-accounting
symmetry on the hybrid model, and metrics on/off greedy identity."""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import FaultPlan, FaultSpec
from repro.serving.metrics import (Counter, Gauge, Histogram, HistSnap,
                                   MetricsRegistry, format_pending,
                                   percentile_from_counts)
from repro.serving.trace import TraceRecorder, to_chrome_trace

DATA = os.path.join(os.path.dirname(__file__), "data")


# ==================================================== metrics core (pure) ==
def test_histogram_percentile_rule_hand_computed():
    """One decade per bucket so the rule is checkable on paper.  Bounds:
    1e-3, 1e-2, 1e-1, 1, 10, 100, 1000."""
    h = Histogram("t", lo=1e-3, hi=1e3, per_decade=1)
    assert h.bounds == tuple(10.0 ** e for e in range(-3, 4))
    for v in (0.0005, 0.05, 5.0):
        h.observe(v)
    # count=3.  p50: rank=ceil(.5*3)=2 -> cumulative reaches 2 in the
    # bucket holding 0.05 (first bound >= 0.05 is 0.1) -> report 0.1
    assert h.percentile(0.50) == 0.1
    # p99: rank=3 -> bucket bound 10, clamped to observed max 5.0
    assert h.percentile(0.99) == 5.0
    # p01: rank=1 -> first bucket bound 1e-3 (observed min 5e-4 is below
    # the bound; the clamp only pulls into [min,max], 1e-3 is inside)
    assert h.percentile(0.01) == 1e-3
    s = h.summary()
    assert s["count"] == 3 and s["min"] == 0.0005 and s["max"] == 5.0
    assert s["sum"] == pytest.approx(5.0505)


def test_histogram_single_value_is_exact_everywhere():
    h = Histogram("t")
    for _ in range(5):
        h.observe(0.0123)
    for q in (0.01, 0.5, 0.9, 0.99, 1.0):
        assert h.percentile(q) == 0.0123   # [min,max] clamp collapses


def test_histogram_overflow_reports_observed_max():
    h = Histogram("t", lo=1e-3, hi=1.0, per_decade=1)
    h.observe(0.5)
    h.observe(7500.0)                      # > hi: overflow bucket
    assert h.percentile(0.99) == 7500.0
    assert h.counts().buckets[-1] == 1


def test_histogram_bucket_edges_are_exact():
    """observe(bound) lands IN that bound's bucket (<=), not the next."""
    h = Histogram("t", lo=1e-3, hi=1e3, per_decade=1)
    h.observe(0.01)
    snap = h.counts()
    assert snap.buckets[h.bounds.index(0.01)] == 1


def test_histsnap_delta_percentiles():
    """Subtracting snapshots isolates the observations in between."""
    h = Histogram("t", lo=1e-3, hi=1e3, per_decade=1)
    h.observe(0.5)
    s0 = h.counts()
    for v in (0.05, 0.05, 0.05, 20.0):
        h.observe(v)
    d = h.counts() - s0
    assert d.count == 4 and d.sum == pytest.approx(20.15)
    # rank=ceil(.5*4)=2 -> bucket bound 0.1 (no min/max clamp in deltas)
    assert d.percentile(0.50) == 0.1
    # rank=4 -> the 20.0 landed in the 100-bound bucket
    assert d.percentile(0.99) == 100.0
    assert d.vmin is None and d.vmax is None
    with pytest.raises(ValueError, match="different bounds"):
        d - Histogram("u", lo=1e-2, hi=1e2, per_decade=1).counts()


def test_percentile_from_counts_empty():
    assert percentile_from_counts((1.0,), (0, 0), 0.5) == 0.0


def test_counter_gauge_labels_and_registry():
    reg = MetricsRegistry(clock=lambda: 42.0)
    assert reg.now() == 42.0
    c = reg.counter("faults")
    c.inc(site="page_alloc")
    c.inc(2, site="page_alloc")
    c.inc(site="swap_drain")
    assert c.value(site="page_alloc") == 3 and c.total() == 4
    assert c.snapshot() == {"site=page_alloc": 3, "site=swap_drain": 1}
    with pytest.raises(ValueError, match="< 0"):
        c.inc(-1)
    g = reg.gauge("pool")
    g.set(7, group="kv")
    g.set(9, group="kv")               # gauges overwrite
    assert g.value(group="kv") == 9
    assert reg.counter("faults") is c  # same name -> same instrument
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("faults")
    snap = reg.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"]["faults"]["site=swap_drain"] == 1


# =========================================================== trace (pure) ==
def test_trace_ring_buffers_bound_memory():
    t = [0.0]
    rec = TraceRecorder(lambda: t[0], journal_len=4, keep_finished=2)
    for step in range(10):
        rec.begin_step(step)
        t[0] += 0.001
        rec.end_step([0], pages_used=1, pages_free=7, pages_grown=0,
                     pages_cow=0, pages_evicted=0)
    assert len(rec.journal) == 4
    assert [s.step for s in rec.journal] == [6, 7, 8, 9]
    for uid in range(5):
        rec.event(uid, "submit")
        rec.finish(uid)
    assert len(rec.finished) == 2
    assert [tl.uid for tl in rec.finished] == [3, 4]
    assert not rec.live


def test_trace_disabled_records_nothing_but_still_tells_time():
    t = [5.0]
    rec = TraceRecorder(lambda: t[0], enabled=False)
    assert rec.event(1, "submit") == 5.0   # callers still get a timestamp
    rec.begin_step(0)
    rec.note_chunk(0, 1, 8)
    rec.end_step([0], pages_used=0, pages_free=0, pages_grown=0,
                 pages_cow=0, pages_evicted=0)
    assert not rec.journal and not rec.live and not rec.finished


def _golden_recorder():
    """Deterministic recorder: two steps, one request that prefills, emits a
    token, is preempted, swaps back in, and finishes — every export shape
    (slices, counters, instants, flow arrows) in one small trace."""
    t = [0.0]
    rec = TraceRecorder(lambda: t[0])
    tl = rec.timeline(7)
    tl.add(0.0, "submit", prompt=5)
    rec.begin_step(0)
    rec.note_chunk(0, 7, 5)
    tl.add(0.0005, "admit", slot=0, cached_tokens=0)
    rec.note_fault("page_alloc")
    t[0] = 0.001
    rec.end_step([], pages_used=2, pages_free=6, pages_grown=2,
                 pages_cow=0, pages_evicted=0)
    tl.add(0.001, "first_token", slot=0)
    rec.begin_step(1)
    tl.add(0.0015, "preempt", slot=0, bytes=1024)
    rec.note_preempt(7, 0)
    tl.add(0.0018, "swap_in", slot=1)
    rec.note_resume(7, 1)
    t[0] = 0.002
    rec.end_step([1], pages_used=3, pages_free=5, pages_grown=1,
                 pages_cow=0, pages_evicted=0)
    tl.add(0.002, "finish", reason="completed")
    tl.finish_t = 0.002
    rec.finish(7)
    return rec


def test_chrome_trace_golden():
    """Byte-stable export: field order, µs rounding, flow-event pairing all
    pinned by a golden file."""
    obj = to_chrome_trace(_golden_recorder(), base=0.0, n_slots=2)
    got = json.dumps(obj, indent=1) + "\n"
    with open(os.path.join(DATA, "golden_trace.json")) as f:
        want = f.read()
    assert got == want


def test_chrome_trace_structure():
    obj = to_chrome_trace(_golden_recorder(), base=0.0, n_slots=2)
    evs = obj["traceEvents"]
    by_ph = {}
    for e in evs:
        by_ph.setdefault(e["ph"], []).append(e)
    # metadata: process + queue track + 2 slot tracks
    assert len(by_ph["M"]) == 4
    # the preempt->resume flow start/finish pair shares id and category
    (s,), (f,) = by_ph["s"], by_ph["f"]
    assert s["id"] == f["id"] == 7 and s["cat"] == f["cat"] == "swap"
    assert f["bp"] == "e"
    # flow endpoints sit on the tracks the request moved between
    assert s["tid"] == 1 and f["tid"] == 2
    # counter samples carry pool occupancy
    assert by_ph["C"][0]["args"] == {"used": 2, "free": 6}
    # fault probes are emitted as instants on the step track
    assert any(e["name"] == "fault:page_alloc" for e in by_ph["i"])
    # timestamps are µs since base, ns-rounded
    first_chunk = next(e for e in by_ph["X"] if e["name"] == "prefill_chunk")
    assert first_chunk["ts"] == 0.0 and first_chunk["dur"] == 1000.0


# ================================================= engine, faked clock ==
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("codellama-7b", smoke=True)
    return cfg, api.init_model(jax.random.PRNGKey(0), cfg)


def test_engine_latency_histograms_hand_computed(setup):
    """Fake clock ticking 1s per step: every latency the engine derives is an
    exact integer count of steps, checkable by hand.  TTFT/e2e must match
    the request's own engine-recorded timestamps, single-value exactness
    makes p50==p99, and the ITL gaps are [0, 1, 1] (the first decode shares
    the prefill-completion mixed step, then one token per step at B=1)."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=32, backend="xla")
    t = [0.0]
    eng._clock = lambda: t[0]
    r = Request(uid=1, prompt=np.arange(2, 8).astype(np.int32), max_tokens=4)
    eng.submit(r)
    assert r.arrival_t == 0.0
    while r.done_t is None:
        t[0] += 1.0
        eng.step()
    snap = eng.metrics_snapshot()
    lat = snap["latency"]

    # TTFT: one observation == the engine's own first_token stamp
    ttft = r.first_token_t - r.arrival_t
    assert ttft >= 1.0 and ttft == int(ttft)
    assert lat["ttft_s"]["count"] == 1
    assert lat["ttft_s"]["p50"] == lat["ttft_s"]["p99"] == ttft
    assert lat["ttft_s"]["mean"] == ttft

    # ITL: max_tokens-1 gaps.  The first decode shares the mixed step that
    # completed the prefill (gap 0); every later token is one clock tick.
    assert lat["itl_s"]["count"] == len(r.output) - 1 == 3
    assert lat["itl_s"]["p50"] == lat["itl_s"]["p99"] == 1.0
    assert lat["itl_s"]["min"] == 0.0 and lat["itl_s"]["max"] == 1.0
    assert lat["itl_s"]["mean"] == pytest.approx(2 / 3)

    # the finished timeline moved to the bounded archive, in event order
    tls = [tl for tl in eng.trace.finished if tl.uid == 1]
    assert len(tls) == 1

    # e2e and queue wait close the loop on the same clock
    assert lat["e2e_s"]["count"] == 1
    assert lat["e2e_s"]["p50"] == r.done_t - r.arrival_t
    assert lat["queue_wait_s"]["count"] == 1
    assert lat["queue_wait_s"]["p50"] == tls[0].admit_t - r.arrival_t == 1.0

    names = [n for _, n, _ in tls[0].events]
    assert names[0] == "submit" and names[-1] == "finish"
    assert "first_token" in names and "admit" in names


def test_pending_report_folds_metrics_snapshot(setup):
    """_pending_report is a rendering of metrics_snapshot, not a second
    formatting path: same text, and the snapshot carries phase + remaining
    deadline for queued and running requests alike."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=32, backend="xla")
    t = [0.0]
    eng._clock = lambda: t[0]
    r1 = Request(uid=1, prompt=np.arange(2, 8).astype(np.int32), max_tokens=8)
    r2 = Request(uid=2, prompt=np.arange(2, 9).astype(np.int32), max_tokens=4,
                 deadline_s=9.0)
    eng.submit(r1)
    eng.submit(r2)
    t[0] = 1.0
    eng.step()                 # r1 runs, r2 queued with 8s left
    snap = eng.metrics_snapshot()
    by_uid = {p["uid"]: p for p in snap["pending"]}
    assert by_uid[1]["slot"] == 0 and by_uid[1]["phase"] in ("prefilling",
                                                            "decoding")
    assert by_uid[2]["phase"] == "queued" and by_uid[2]["slot"] is None
    assert by_uid[2]["deadline_left_s"] == 8.0
    assert by_uid[1]["deadline_left_s"] is None
    report = eng._pending_report()
    assert report == format_pending(snap)  # frozen clock -> identical text
    assert "uid=2 phase=queued prompt=7 out=0/4 retries=0 deadline=8.000s" \
        in report
    assert "pager: free=" in report


def test_fault_sink_feeds_labeled_counter(setup):
    """Every FaultPlan fire lands in the ``faults_fired_total`` counter under
    its site label — per-site counts reconcile with the plan's own ledger."""
    cfg, params = setup
    plan = FaultPlan([FaultSpec("page_alloc", every=3, times=2),
                      FaultSpec("page_grow", op=0, times=1)], seed=0)
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=24, page_size=4,
                        num_pages=1 + 7, backend="xla", fault_plan=plan,
                        max_prefill_tokens=8)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(2, cfg.vocab_size,
                                               5 + i).astype(np.int32),
                           max_tokens=6))
    eng.run_until_drained(max_steps=600)
    assert plan.total_injected > 0, "plan never fired — sizing broke"
    ctr = eng.metrics.counter("faults_fired_total")
    for site, n in plan.injected.items():
        assert ctr.value(site=site) == n, (site, n, ctr.snapshot())
    assert ctr.total() == plan.total_injected == eng.stats.faults_injected
    # and the step journal marked every fault's step
    journal_faults = [s for rec in eng.trace.journal for s in rec.faults]
    assert len(journal_faults) == plan.total_injected


def test_swap_byte_accounting_symmetry_hybrid():
    """Satellite regression: swap-in must count the same bytes swap-out did,
    including the fixed-rows (SSM) state — the two sides of EngineStats
    accounting stay equal after every image round-trips."""
    cfg = get_config("zamba2-7b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    lens = (5, 9, 7, 12)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        lens[i % 4]).astype(np.int32),
                    max_tokens=6)
            for i in range(5)]
    eng = ServingEngine(params, cfg, batch_size=3, max_seq=24, page_size=4,
                        num_pages=1 + 7, backend="xla", max_prefill_tokens=8)
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained(max_steps=600)
    assert stats.preemptions > 0 and stats.resumes > 0
    assert stats.swapped_out_bytes == stats.swapped_in_bytes > 0
    assert stats.swapped_fixed_bytes == stats.swapped_fixed_in_bytes > 0
    # KV bytes alone are symmetric too (fixed split accounted both sides)
    assert (stats.swapped_out_bytes - stats.swapped_fixed_bytes
            == stats.swapped_in_bytes - stats.swapped_fixed_in_bytes)
    eng.pager.check_invariants()


def test_metrics_on_off_greedy_identity(setup):
    """The whole observability subsystem is host-side bookkeeping: switching
    it off changes no token anywhere (preemption pressure included), and the
    off engine records nothing."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, 4 + i % 5).astype(np.int32)
               for i in range(5)]

    def drive(metrics):
        eng = ServingEngine(params, cfg, batch_size=3, max_seq=24,
                            page_size=4, num_pages=1 + 7, backend="xla",
                            max_prefill_tokens=8, metrics=metrics)
        reqs = [Request(uid=i, prompt=p.copy(), max_tokens=8)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=600)
        return eng, [list(r.output) for r in reqs]

    eng_on, out_on = drive(True)
    eng_off, out_off = drive(False)
    assert out_on == out_off
    assert eng_on.stats.preemptions == eng_off.stats.preemptions
    # on: full recording; off: nothing retained
    assert len(eng_on.trace.journal) == eng_on.stats.steps
    assert eng_on.metrics_snapshot()["latency"]["ttft_s"]["count"] == 5
    assert not eng_off.trace.journal and not eng_off.trace.finished
    assert eng_off.metrics_snapshot()["latency"]["ttft_s"]["count"] == 0
    # the snapshot itself stays well-formed with metrics off
    assert eng_off.metrics_snapshot()["engine"]["completed"] == 5
