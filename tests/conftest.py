"""Tier-1 test harness glue.

Three jobs, all so ``python -m pytest -x -q`` works on a clean machine:

1. put ``src/`` on ``sys.path`` (no install / PYTHONPATH needed);
2. if ``hypothesis`` is not installed, register a shim module so the four
   property-test modules still *collect*; their ``@given`` tests turn into
   skips while every plain test in those modules keeps running
   (install ``requirements-dev.txt`` to run the property tests too);
3. a dependency-free per-test timeout (SIGALRM) so a wedged test fails loudly
   instead of hanging the suite — tune via ``REPRO_TEST_TIMEOUT`` (seconds,
   0 disables; CI adds a job-level timeout on top).
"""
from __future__ import annotations

import os
import signal
import sys
import types

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))


# ------------------------------------------------- hypothesis fallback shim --
def _install_hypothesis_shim() -> None:
    class _AnyStrategy:
        """Opaque stand-in: any attribute/call/combinator returns itself."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    def given(*_a, **_k):
        def deco(fn):
            def stub():
                pytest.skip("hypothesis not installed; property test skipped")

            # plain function with NO parameters: pytest must not try to
            # resolve the strategy arguments as fixtures (and no
            # functools.wraps — __wrapped__ would leak the real signature)
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            stub.__module__ = fn.__module__
            return stub

        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    st = types.ModuleType("hypothesis.strategies")
    st.__getattr__ = lambda name: _AnyStrategy()

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = _AnyStrategy()
    hyp.assume = lambda *a, **k: True
    hyp.__is_repro_shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()


# --------------------------------------------------- per-test hang guard ----
DEFAULT_TIMEOUT_S = 300


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    timeout = int(os.environ.get("REPRO_TEST_TIMEOUT", str(DEFAULT_TIMEOUT_S)))
    if timeout <= 0 or not hasattr(signal, "SIGALRM"):
        return (yield)

    def _on_alarm(signum, frame):
        raise TimeoutError(
            f"test exceeded {timeout}s (REPRO_TEST_TIMEOUT to adjust)")

    old = signal.signal(signal.SIGALRM, _on_alarm)
    signal.alarm(timeout)
    try:
        return (yield)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
