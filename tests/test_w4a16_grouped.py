"""Expert-batched grouped W4A16 kernel vs the dequant-einsum oracle, the
model-level MoE / MLA-absorbed integration, and the tiny-t decode fast path
of the 2-D kernel (no recompile across steady-state decode steps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as q
from repro.kernels import ops
from repro.kernels.ref import w4a16_grouped_ref
from repro.kernels.w4a16_grouped import w4a16_grouped_matmul


def _mk(e, c, d, f, g, seed=0, dtype=jnp.float32):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (e, c, d), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (e, d, f), jnp.float32)
    return x, q.quantize(w, group_size=g)


# ------------------------------------------------------------ kernel level --
@pytest.mark.parametrize(
    "e,c,d,f,g",
    [
        (1, 8, 128, 128, 128),      # single expert == 2-D contract
        (8, 16, 128, 128, 128),     # full expert sweep
        (8, 24, 256, 128, 64),      # multi-group contraction, g=64
        (4, 8, 128, 256, 128),      # wide Co
        (2, 100, 128, 128, 128),    # c not a multiple of the block
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_kernel_matches_oracle(e, c, d, f, g, dtype):
    x, qt = _mk(e, c, d, f, g, dtype=dtype)
    got = w4a16_grouped_matmul(x, qt, block_c=64, block_co=128, interpret=True)
    want = w4a16_grouped_ref(x, qt)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=2e-1 if dtype == jnp.bfloat16 else 1e-4,
    )


def test_grouped_kernel_ragged_capacity_rows_are_zero():
    """Zero-padded capacity slots (ragged MoE dispatch) must produce exactly
    zero output rows — the combine gather relies on it."""
    e, c, d, f, g = 4, 16, 128, 128, 64
    x, qt = _mk(e, c, d, f, g, seed=3)
    filled = jnp.asarray([16, 5, 0, 9])          # per-expert live rows
    mask = jnp.arange(c)[None, :] < filled[:, None]
    x = jnp.where(mask[..., None], x, 0.0)
    got = np.asarray(
        w4a16_grouped_matmul(x, qt, block_c=16, block_co=128, interpret=True))
    want = np.asarray(w4a16_grouped_ref(x, qt))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
    for ei in range(e):
        assert (got[ei, int(filled[ei]):] == 0).all()


def test_grouped_dispatch_xla_equals_interpret():
    x, qt = _mk(2, 12, 128, 128, 128, seed=5)
    a = ops.w4a16_grouped_matmul(x, qt, backend="xla")
    b = ops.w4a16_grouped_matmul(x, qt, backend="interpret",
                                 block_c=16, block_co=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-4)


def test_grouped_kernel_rejects_2d_weight():
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (2, 8, 128), jnp.float32)
    qt = q.quantize(jax.random.normal(kw, (128, 128), jnp.float32),
                    group_size=128)
    with pytest.raises(ValueError):
        w4a16_grouped_matmul(x, qt, interpret=True)


def test_stacked_quantize_equals_per_expert_quantize():
    """Stacked [E, Ci, Co] quantization must be bitwise the stack of
    independent 2-D quantizations (first-class leading dims)."""
    w = jax.random.normal(jax.random.PRNGKey(7), (3, 128, 64), jnp.float32)
    qt = q.quantize(w, group_size=64)
    assert qt.shape == (3, 128, 64) and qt.ndim == 3 and qt.group_size == 64
    for ei in range(3):
        one = q.quantize(w[ei], group_size=64)
        np.testing.assert_array_equal(np.asarray(qt[ei].packed),
                                      np.asarray(one.packed))
        np.testing.assert_array_equal(np.asarray(qt[ei].scales),
                                      np.asarray(one.scales))
        np.testing.assert_array_equal(np.asarray(qt[ei].zeros),
                                      np.asarray(one.zeros))
        np.testing.assert_allclose(
            np.asarray(q.dequantize(qt, jnp.float32)[ei]),
            np.asarray(q.dequantize(one, jnp.float32)), atol=0)


# -------------------------------------------------------------- model level -
def test_apply_moe_quantized_interpret_matches_xla():
    """MoE expert compute with int4 stacked weights: the grouped Pallas
    kernel (interpret) must agree with the dequant-einsum XLA path."""
    from repro.configs import get_config
    from repro.models import api, mlp as M

    cfg = get_config("granite-moe-1b-a400m", smoke=True).with_(dtype="float32")
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, cfg)
    ew = p["experts"]
    p["experts"] = {k: q.quantize(v, group_size=16) for k, v in ew.items()}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    y_x, _ = M.apply_moe(p, x, cfg, backend="xla")
    y_i, _ = M.apply_moe(p, x, cfg, backend="interpret")
    np.testing.assert_allclose(np.asarray(y_i), np.asarray(y_x),
                               rtol=2e-4, atol=2e-4)


def test_mla_absorbed_decode_never_dequantizes():
    """Quantized MLA decode must use the stacked absorbed int4 weights; a
    quantized ``wkv_b`` without them is a wiring bug and raises."""
    from repro.configs import get_config
    from repro.configs.base import QuantConfig
    from repro.core import calibration as C
    from repro.core.apply import smoothquant_plus
    from repro.models import api, attention as A

    cfg = get_config("deepseek-v2-236b", smoke=True).with_(dtype="float32")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    batches = C.synthetic_calibration_set(cfg, n_seqs=1, seq_len=12)
    qp, _ = smoothquant_plus(params, cfg, batches, QuantConfig(group_size=16),
                             step=0.5)
    mixer0 = jax.tree.map(lambda l: l[0], qp["layers"]["mixer"])
    assert "wkv_b_absorbed" in mixer0
    assert isinstance(mixer0["wkv_b_absorbed"]["wk_t"], q.QuantizedTensor)
    # decode works through the grouped op on both backends
    prompt = jnp.arange(3, 9)[None]
    _, cache = api.prefill_fn(qp, {"tokens": prompt}, cfg, 16, backend="xla")
    batch = {"token": jnp.asarray([[5]], jnp.int32),
             "position": jnp.asarray([6], jnp.int32)}
    dx, _ = api.decode_fn(qp, batch, cache, cfg, backend="xla")
    di, _ = api.decode_fn(qp, batch, cache, cfg, backend="interpret")
    np.testing.assert_allclose(np.asarray(di), np.asarray(dx),
                               rtol=2e-4, atol=2e-4)
    # the guard: quantized wkv_b with the absorbed weights stripped raises
    broken = dict(mixer0)
    del broken["wkv_b_absorbed"]
    with pytest.raises(TypeError):
        A._mla_absorb_weights(broken, cfg)


# ------------------------------------------------- tiny-t decode fast path --
def test_decode_tiny_t_no_recompile():
    """Steady-state decode (fixed [B, Ci] shape) must reuse one compiled
    trace; a second decode bucket adds exactly one more."""
    from repro.kernels.w4a16_matmul import w4a16_matmul

    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    qt = q.quantize(jax.random.normal(kw, (128, 128), jnp.float32),
                    group_size=128)
    x8 = jax.random.normal(kx, (8, 128), jnp.float32)
    x16 = jax.random.normal(kx, (16, 128), jnp.float32)
    base = w4a16_matmul._cache_size()
    for _ in range(3):
        w4a16_matmul(x8, qt, interpret=True).block_until_ready()
    assert w4a16_matmul._cache_size() == base + 1, "decode step recompiled"
    for _ in range(2):
        w4a16_matmul(x16, qt, interpret=True).block_until_ready()
    assert w4a16_matmul._cache_size() == base + 2
    for _ in range(2):  # back to the first bucket: still cached
        w4a16_matmul(x8, qt, interpret=True).block_until_ready()
    assert w4a16_matmul._cache_size() == base + 2


def test_decode_tiny_t_matches_ref():
    """The pinned-bt fast path is numerically the same kernel."""
    from repro.kernels.ref import w4a16_matmul_ref
    from repro.kernels.w4a16_matmul import w4a16_matmul

    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    qt = q.quantize(jax.random.normal(kw, (128, 128), jnp.float32),
                    group_size=64)
    for t in (1, 8, 13, 64):
        x = jax.random.normal(kx, (t, 128), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(w4a16_matmul(x, qt, interpret=True)),
            np.asarray(w4a16_matmul_ref(x, qt)), rtol=1e-5, atol=1e-4)
