"""Sharding rules, roofline analytics, HLO collective parsing, and a
mini end-to-end pjit train step on a local 1-device mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import SHAPES_BY_NAME, TrainConfig, QuantConfig
from repro.launch import hlo_analysis as HA
from repro.launch import jaxpr_cost as JC
from repro.launch import roofline as RL
from repro.models import api
from repro.optim import adamw
from repro.sharding import rules


def _mesh16():
    # 16x16 spec-building only needs axis names/sizes, not real devices:
    # use a tiny abstract mesh via jax.sharding.AbstractMesh.  Its ctor
    # flipped between ((name, size), ...) pairs and (sizes, names) across
    # jax releases — accept whichever this version ships.
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((("data", 16), ("model", 16)))
    except TypeError:
        return AbstractMesh((16, 16), ("data", "model"))


def test_param_specs_dense_tp():
    cfg = get_config("mistral-large-123b")
    shapes = jax.eval_shape(lambda: api.init_model(jax.random.PRNGKey(0), cfg))
    specs = rules.param_specs(shapes, _mesh16(), cfg)
    flat = _flatten_specs(specs)
    assert flat["embed/table"] == P("model", None)
    assert flat["layers/mixer/wq/w"] == P(None, None, "model")
    assert flat["layers/mixer/wo/w"] == P(None, "model", None)
    # mistral kv=8 < 16 → kv replicated
    assert flat["layers/mixer/wk/w"] == P()
    assert flat["layers/mlp/down/w"] == P(None, "model", None)


def _flatten_specs(specs):
    return {
        rules._path_str(p): s
        for p, s in jax.tree_util.tree_flatten_with_path(specs)[0]
    }


def test_param_specs_zamba_kv_sharded():
    cfg = get_config("zamba2-7b")  # kv=32 divisible by 16 → sharded
    shapes = jax.eval_shape(lambda: api.init_model(jax.random.PRNGKey(0), cfg))
    specs = rules.param_specs(shapes, _mesh16(), cfg)
    flat = _flatten_specs(specs)
    assert flat["shared/mixer/wk/w"] == P(None, "model")
    assert flat["groups/mixer/in_x/w"] == P(None, None, None, "model")
    # replicated (padded spec is all-None)
    assert all(a is None for a in flat["groups/mixer/in_bc/w"])


def test_quantized_param_specs_follow_fp():
    from repro.core.apply import quantize_params

    cfg = get_config("mistral-large-123b")
    shapes = jax.eval_shape(lambda: api.init_model(jax.random.PRNGKey(0), cfg))
    qshapes = jax.eval_shape(lambda p: quantize_params(p, cfg, QuantConfig())[0], shapes)
    specs = rules.param_specs(qshapes, _mesh16(), cfg)
    flat = _flatten_specs(specs)
    assert flat["layers/mixer/wq/w/packed"] == P(None, None, "model")
    # scales keep only the output-axis sharding
    assert flat["layers/mixer/wq/w/scales"] == P(None, None, "model")
    assert flat["layers/mlp/down/w/scales"] == P(None, None, None)


def test_quantized_moe_specs_ep_and_cosharded():
    """Stacked quantized expert leaves shard the expert dim (EP) and the
    packed/scales/zeros trio stays co-sharded on every non-group axis."""
    from repro.core.apply import quantize_params

    cfg = get_config("deepseek-v2-236b")
    shapes = jax.eval_shape(lambda: api.init_model(jax.random.PRNGKey(0), cfg))
    qshapes = jax.eval_shape(
        lambda p: quantize_params(p, cfg, QuantConfig())[0], shapes)
    specs = rules.param_specs(qshapes, _mesh16(), cfg)
    flat = _flatten_specs(specs)
    # experts [L, E, Ci(/2|/G), Co]: E=160 on model (EP); contraction unsharded
    for leaf in ("gate", "up", "down"):
        for field in ("packed", "scales", "zeros"):
            assert flat[f"layers/mlp/experts/{leaf}/{field}"] == P(
                None, "model", None, None), (leaf, field)
    # MLA absorbed decode weights [L, H, Ci', Co']: heads on model (TP)
    for leaf in ("wk_t", "wv"):
        for field in ("packed", "scales", "zeros"):
            assert flat[f"layers/mixer/wkv_b_absorbed/{leaf}/{field}"] == P(
                None, "model", None, None), (leaf, field)


def test_quantized_trio_cosharded_everywhere():
    """Property over ALL quantized leaves: scales/zeros == packed's spec with
    only the group axis (second-to-last) dropped — never a lead-axis or
    output-axis divergence (a mis-coshard would misalign dequant groups)."""
    from repro.core.apply import quantize_params

    for arch in ("deepseek-v2-236b", "mistral-large-123b"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda: api.init_model(jax.random.PRNGKey(0), cfg))
        qshapes = jax.eval_shape(
            lambda p: quantize_params(p, cfg, QuantConfig())[0], shapes)
        flat = _flatten_specs(rules.param_specs(qshapes, _mesh16(), cfg))
        packed = {k[: -len("/packed")]: v for k, v in flat.items()
                  if k.endswith("/packed")}
        assert packed, arch
        for base, pspec in packed.items():
            for field in ("scales", "zeros"):
                fspec = flat[f"{base}/{field}"]
                assert len(fspec) == len(pspec) or not tuple(fspec), base
                if tuple(fspec):
                    assert tuple(fspec)[:-2] == tuple(pspec)[:-2], base
                    assert tuple(fspec)[-1] == tuple(pspec)[-1], base


def test_opt_specs_zero_shards_over_data():
    cfg = get_config("llama3.2-3b")
    shapes = jax.eval_shape(lambda: api.init_model(jax.random.PRNGKey(0), cfg))
    tc = TrainConfig()
    opt_shape = jax.eval_shape(lambda p: adamw.init_opt_state(p, tc), shapes)
    pspecs = rules.param_specs(shapes, _mesh16(), cfg)
    ospecs = rules.opt_specs(opt_shape, pspecs, _mesh16())
    flat = _flatten_specs(ospecs.mu)
    # wq moment: (28, 3072, 3072) param spec (None,None,model) → data on dim1
    assert flat["layers/mixer/wq/w"] == P(None, "data", "model")


def test_cache_specs_decode():
    cfg = get_config("mistral-large-123b")
    shape = SHAPES_BY_NAME["decode_32k"]
    cache = api.cache_specs(cfg, shape)
    specs = rules.cache_specs_tree(cache, _mesh16())
    flat = _flatten_specs(specs)
    # [L, B, S, Hkv, Dh]: batch 128 → data, seq 32768 → model (SP decode)
    assert flat["layers/k"] == P(None, ("data",), ("model",), None, None)


def test_cache_specs_long_context_batch1():
    cfg = get_config("rwkv6-7b")
    shape = SHAPES_BY_NAME["long_500k"]
    cache = api.cache_specs(cfg, shape)
    specs = rules.cache_specs_tree(cache, _mesh16())
    flat = _flatten_specs(specs)
    # rwkv state [L, B=1, H=64, K, V] → heads on model
    assert flat["layers/wkv"] == P(None, None, "model", None, None)


# ------------------------------------------------------------- analytics ----
def test_jaxpr_cost_counts_scan_trips():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = JC.jaxpr_cost(f, x, w)
    assert c["flops"] == 10 * 2 * 128**3


def test_jaxpr_cost_sees_remat_recompute():
    def f(x, w):
        def body(c, wi):
            return jax.checkpoint(lambda c, wi: jnp.tanh(c @ wi))(c, wi), None
        return jnp.sum(jax.lax.scan(body, x, w)[0])

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    fwd = JC.jaxpr_cost(f, x, w)["flops"]
    grad = JC.jaxpr_cost(lambda x, w: jax.grad(
        lambda x: f(x, w))(x), x, w)["flops"]
    # backward with remat ≥ 3× forward matmul flops (fwd recompute + 2 bwd)
    assert grad >= 2.9 * fwd


def test_hlo_collective_parser_toy():
    hlo = """
HLO module m
%cond (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(s32[] %iv, s32[] %c), direction=LT
}
%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4]{0} all-reduce(f32[4]{0} %x), to_apply=%add
  ROOT %t = (s32[], f32[4]) tuple(%iv, %ar)
}
ENTRY %main () -> f32[4] {
  %w = (s32[], f32[4]) while((s32[], f32[4]) %init), condition=%cond, body=%body
  %ag = f32[8]{0} all-gather(f32[4]{0} %y), dimensions={0}
  ROOT %r = f32[4] get-tuple-element(%w), index=1
}
"""
    coll = HA.collective_bytes(hlo)
    assert coll["all-reduce"] == 16 * 7   # inside while ×7
    assert coll["all-gather"] == 32       # entry ×1


def test_roofline_terms_and_bottleneck():
    rl = RL.Roofline(flops=1e15, hbm_bytes=1e12, coll_bytes=1e13, chips=256,
                     model_flops=8e14)
    d = rl.to_dict()
    assert abs(d["t_compute_s"] - 1e15 / (256 * RL.PEAK_FLOPS)) < 1e-12
    assert d["bottleneck"] == "collective"
    assert 0 < d["roofline_fraction"] <= 1.0


def test_model_flops_moe_counts_active_only():
    cfg = get_config("deepseek-v2-236b")
    shape = SHAPES_BY_NAME["train_4k"]
    shapes = jax.eval_shape(lambda: api.init_model(jax.random.PRNGKey(0), cfg))
    ntot, nemb = RL.count_params(shapes)
    mf = RL.model_flops_estimate(cfg, shape, ntot, nemb)
    dense_equiv = 6 * (ntot - nemb) * shape.global_batch * shape.seq_len
    assert mf < 0.5 * dense_equiv  # top-6/160 is sparse


# ------------------------------------------------ 1-device pjit smoke -------
def test_pjit_train_step_local_mesh():
    from repro.train.trainer import make_train_step
    from jax.sharding import NamedSharding

    cfg = get_config("codellama-7b", smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    tc = TrainConfig()
    opt = adamw.init_opt_state(params, tc)
    pspecs = rules.param_specs(params, mesh, cfg)
    named = lambda t: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), t,
        is_leaf=lambda x: isinstance(x, P))
    step = jax.jit(make_train_step(cfg, tc, "xla"),
                   in_shardings=(named(pspecs), None, None),
                   out_shardings=(named(pspecs), None, None))
    toks = jnp.zeros((2, 16), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    with mesh:
        p2, o2, m = step(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
