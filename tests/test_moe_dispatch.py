"""Property tests for the MoE sort-based dispatch (the §Perf-rewritten path).

Invariants:
- every kept token-slot lands in the buffer row of ITS expert;
- per-expert occupancy never exceeds capacity;
- with dropless capacity the MoE equals the dense per-token expert sum;
- the block-local (hierarchical) dispatch equals the global one when
  capacity is dropless.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.mlp import _dispatch_indices, apply_moe, init_moe


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 200),
    e=st.sampled_from([2, 4, 8]),
    cap=st.integers(1, 64),
    seed=st.integers(0, 10_000),
)
def test_dispatch_indices_invariants(n, e, cap, seed):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, e, n).astype(np.int32))
    buf_idx, keep = _dispatch_indices(ids, e, cap)
    buf_idx, keep = np.asarray(buf_idx), np.asarray(keep)
    # kept slots land inside their expert's capacity range
    experts = buf_idx // cap
    assert (experts[keep] == np.asarray(ids)[keep]).all()
    # no two kept slots share a buffer row
    rows = buf_idx[keep]
    assert len(np.unique(rows)) == len(rows)
    # occupancy ≤ capacity, and nothing is dropped while space remains
    counts = np.bincount(np.asarray(ids), minlength=e)
    kept_per_e = np.bincount(np.asarray(ids)[keep], minlength=e)
    np.testing.assert_array_equal(kept_per_e, np.minimum(counts, cap))


def _moe_cfg(e=4, k=2, cf=None):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=16, vocab_size=64, dtype="float32",
        moe=MoEConfig(num_experts=e, top_k=k, d_expert=16,
                      capacity_factor=cf if cf is not None else float(e)),
    )


def _dense_reference(p, x, cfg):
    """Every token through its top-k experts, no capacity — ground truth."""
    m = cfg.moe
    b, t, d = x.shape
    xf = x.reshape(-1, d).astype(jnp.float32)
    logits = xf @ p["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / w.sum(-1, keepdims=True)
    gate = p["experts"]["gate"].astype(jnp.float32)
    up = p["experts"]["up"].astype(jnp.float32)
    down = p["experts"]["down"].astype(jnp.float32)
    # per-token dense evaluation of all experts, then select
    h = jax.nn.silu(jnp.einsum("nd,edf->nef", xf, gate)) * jnp.einsum(
        "nd,edf->nef", xf, up)
    y_all = jnp.einsum("nef,efd->ned", h, down)
    sel = jnp.take_along_axis(y_all, idx[..., None], axis=1)
    return (sel * w[..., None]).sum(1).reshape(b, t, d)


def test_moe_matches_dense_reference_dropless():
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    got, _ = apply_moe(p, x, cfg, backend="xla")
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_bounded():
    """With tight capacity the output degrades gracefully (dropped tokens
    produce zero expert contribution, never garbage)."""
    cfg = _moe_cfg(cf=0.5)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    got, aux = apply_moe(p, x, cfg, backend="xla")
    assert bool(jnp.isfinite(got).all()) and bool(jnp.isfinite(aux))
    dense = _dense_reference(p, x, cfg)
    # dropped-token rows are a subset: error bounded by dense magnitude
    assert float(jnp.linalg.norm(got)) <= float(jnp.linalg.norm(dense)) * 1.5


def test_hierarchical_dispatch_equals_global_dropless(monkeypatch):
    """Block-local dispatch (the §Perf path) == global when dropless."""
    from repro.models import mlp as mlp_mod
    from repro.sharding import hints

    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
    base, _ = apply_moe(p, x, cfg, backend="xla")

    class FakeMesh:  # just enough for nblk selection; constraints stubbed
        shape = {"data": 2, "model": 1}

    monkeypatch.setattr(mlp_mod.H, "current_mesh", lambda: FakeMesh())
    monkeypatch.setattr(mlp_mod.H, "shard_hint", lambda a, *ax: a)
    blocked, _ = apply_moe(p, x, cfg, backend="xla")
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(base),
                               rtol=1e-4, atol=1e-4)
