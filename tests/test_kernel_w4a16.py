"""Pallas W4A16 kernel vs pure-jnp oracle: shape/dtype sweep + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quantize as q
from repro.kernels import ops
from repro.kernels.ref import w4a16_matmul_ref
from repro.kernels.w4a16_matmul import w4a16_matmul, vmem_bytes


def _mk(t, ci, co, g, seed=0, dtype=jnp.float32):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (t, ci), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (ci, co), jnp.float32)
    return x, q.quantize(w, group_size=g)


@pytest.mark.parametrize(
    "t,ci,co,g",
    [
        (8, 128, 128, 128),
        (16, 256, 128, 128),
        (128, 256, 256, 128),
        (64, 256, 512, 64),
        (1, 128, 256, 128),   # decode row
        (300, 384, 256, 128), # t not multiple of block
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref_sweep(t, ci, co, g, dtype):
    x, qt = _mk(t, ci, co, g, dtype=dtype)
    got = w4a16_matmul(x, qt, block_t=128, block_co=128, interpret=True)
    want = w4a16_matmul_ref(x, qt)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=2e-1 if dtype == jnp.bfloat16 else 1e-4,
    )


def test_kernel_batched_input_shape():
    x, qt = _mk(4 * 16, 128, 128, 128, seed=2)
    x3 = x.reshape(4, 16, 128)
    got = w4a16_matmul(x3, qt, block_t=64, block_co=128, interpret=True)
    assert got.shape == (4, 16, 128)
    want = w4a16_matmul_ref(x3, qt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_ops_dispatch_xla_equals_interpret():
    x, qt = _mk(32, 256, 128, 128, seed=3)
    a = ops.w4a16_matmul(x, qt, backend="xla")
    b = ops.w4a16_matmul(x, qt, backend="interpret", block_t=32, block_co=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4)


def test_quantized_linear_bias():
    x, qt = _mk(8, 128, 128, 128, seed=4)
    b = jnp.arange(128, dtype=jnp.float32)
    y = ops.quantized_linear(x, qt, b, backend="xla")
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(w4a16_matmul_ref(x, qt) + b), rtol=1e-6
    )


@settings(max_examples=12, deadline=None)
@given(
    t=st.integers(1, 64),
    ci_groups=st.integers(1, 3),
    co_tiles=st.integers(1, 3),
    g=st.sampled_from([64, 128]),
    seed=st.integers(0, 1000),
)
def test_property_kernel_allclose(t, ci_groups, co_tiles, g, seed):
    ci, co = ci_groups * g, co_tiles * 128
    x, qt = _mk(t, ci, co, g, seed=seed)
    got = w4a16_matmul(x, qt, block_t=64, block_co=128, interpret=True)
    want = w4a16_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


def test_vmem_budget_default_blocks():
    # default block shapes must fit comfortably in 16MB v5e VMEM
    assert vmem_bytes(256, 256, 128) < 4 * 1024 * 1024
