"""Chunked paged prefill: the Pallas chunk kernels (interpret mode) vs the
dense-gather oracle (GQA and MLA, fp16 and int8 pools, ragged prefixes,
partial pages, dead-page poisoning), mixed-step engine greedy identity
(cold / warm / chunked, gather vs kernel), and the drain / stats regression
fixes that rode along."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models import attention as A
from repro.serving import kv_cache as KV
from repro.serving.engine import Request, ServingEngine

ATOL = 1e-2  # bf16 activations; fp32 checks below are much tighter in practice

# chunk cursors: cold slot, mid-page partial prefix, two exact full pages
STARTS = [0, 5, 16]
CHUNKS = [4, 3, 2]          # ragged valid chunk lengths (T_pad = 4)
T = 4


def _paged_state(batch, pages_per_slot, page_size):
    pool_host = KV.PagePool(1 + batch * pages_per_slot, page_size, batch,
                            pages_per_slot)
    for s in range(batch):
        pool_host.alloc(s, pages_per_slot)
    return pool_host, jnp.asarray(pool_host.table())


def _fill(pool, seed):
    """Random pool contents (all pages, including trash-page garbage)."""
    out = {}
    for i, (k, v) in enumerate(sorted(pool.items())):
        kk = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        if v.dtype == jnp.int8:
            out[k] = jax.random.randint(kk, v.shape, -127, 128, jnp.int8)
        elif k.endswith("_s"):
            out[k] = jax.random.uniform(kk, v.shape, jnp.float32, 1e-3, 2e-2)
        else:
            out[k] = jax.random.normal(kk, v.shape, jnp.float32).astype(v.dtype)
    return out


def _chunk_args(cfg, b=len(STARTS), ps=8, pages=4, seed=2):
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, T, cfg.d_model),
                          cfg.jdtype)
    _, table = _paged_state(b, pages, ps)
    starts = jnp.asarray(STARTS, jnp.int32)
    chunks = jnp.asarray(CHUNKS, jnp.int32)
    return x, table, starts, chunks


@pytest.mark.parametrize("kv_quant", [False, True])
def test_gqa_chunk_kernel_matches_gather(kv_quant):
    cfg = get_config("codellama-7b", smoke=True).with_(kv_quant=kv_quant)
    b, ps, pages = len(STARTS), 8, 4
    p = A.init_gqa(jax.random.PRNGKey(0), cfg)
    pool = _fill(A.init_gqa_page_pool(cfg, 1 + b * pages, ps), seed=1)
    x, table, starts, chunks = _chunk_args(cfg)
    y_ref, pool_ref = A.gqa_prefill_chunk(
        p, x, pool, table, starts, chunks,
        cfg.with_(paged_attn_impl="gather"), backend="xla")
    y_ker, pool_ker = A.gqa_prefill_chunk(
        p, x, pool, table, starts, chunks,
        cfg.with_(paged_attn_impl="pallas_interpret"), backend="xla")
    np.testing.assert_allclose(
        np.asarray(y_ker, np.float32), np.asarray(y_ref, np.float32),
        atol=ATOL, rtol=ATOL)
    # the chunk scatter path is shared: updated pools must be identical
    for key in pool_ref:
        np.testing.assert_array_equal(np.asarray(pool_ref[key]),
                                      np.asarray(pool_ker[key]))


@pytest.mark.parametrize("kv_quant", [False, True])
def test_mla_chunk_kernel_matches_gather(kv_quant):
    cfg = get_config("deepseek-v2-236b", smoke=True).with_(kv_quant=kv_quant)
    b, ps, pages = len(STARTS), 8, 4
    p = A.init_mla(jax.random.PRNGKey(0), cfg)
    pool = _fill(A.init_mla_page_pool(cfg, 1 + b * pages, ps), seed=1)
    x, table, starts, chunks = _chunk_args(cfg)
    y_ref, pool_ref = A.mla_prefill_chunk(
        p, x, pool, table, starts, chunks,
        cfg.with_(paged_attn_impl="gather"), backend="xla")
    y_ker, pool_ker = A.mla_prefill_chunk(
        p, x, pool, table, starts, chunks,
        cfg.with_(paged_attn_impl="pallas_interpret"), backend="xla")
    np.testing.assert_allclose(
        np.asarray(y_ker, np.float32), np.asarray(y_ref, np.float32),
        atol=ATOL, rtol=ATOL)
    for key in pool_ref:
        np.testing.assert_array_equal(np.asarray(pool_ref[key]),
                                      np.asarray(pool_ker[key]))


def test_gqa_chunk_kernel_ignores_dead_page_garbage():
    """Pool rows past each slot's prefix — dead pages, the trash page, and
    the dead tail *inside* a live partial page — are poisoned with huge
    values; the chunk kernel's masks/guards must keep them out bit-exactly."""
    cfg = get_config("codellama-7b", smoke=True)
    b, ps, pages = len(STARTS), 8, 4
    p = A.init_gqa(jax.random.PRNGKey(0), cfg)
    pool = _fill(A.init_gqa_page_pool(cfg, 1 + b * pages, ps), seed=1)
    x, table, starts, chunks = _chunk_args(cfg)
    impl = cfg.with_(paged_attn_impl="pallas_interpret")
    y0, _ = A.gqa_prefill_chunk(p, x, pool, table, starts, chunks, impl,
                                backend="xla")
    dead = np.ones((1 + b * pages, ps), bool)     # poison everything...
    tbl = np.asarray(table)
    for bi, start in enumerate(STARTS):
        for pos in range(start):                  # ...except live prefix rows
            dead[tbl[bi, pos // ps], pos % ps] = False
    mask = jnp.asarray(dead)[:, :, None, None]
    poisoned = dict(pool, k=jnp.where(mask, 1e4, pool["k"]),
                    v=jnp.where(mask, 1e4, pool["v"]))
    y1, _ = A.gqa_prefill_chunk(p, x, poisoned, table, starts, chunks, impl,
                                backend="xla")
    np.testing.assert_array_equal(np.asarray(y0, np.float32),
                                  np.asarray(y1, np.float32))


# ------------------------------------------------------------ engine level --
ENGINE_CASES = [("codellama-7b", False), ("codellama-7b", True),
                ("deepseek-v2-236b", False), ("deepseek-v2-236b", True)]


@pytest.mark.parametrize("arch,kv_quant", ENGINE_CASES)
def test_engine_greedy_identity_cold_warm_mixed(arch, kv_quant):
    """Greedy outputs are token-identical across every serving path a prompt
    can take: stop-the-world single-chunk prefill (cold), token-budget mixed
    chunks, the Pallas chunk kernel vs the gather oracle, and warm chunked
    prefill behind a cached prefix."""
    cfg = get_config(arch, smoke=True).with_(kv_quant=kv_quant)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    p1 = rng.integers(2, cfg.vocab_size, size=12).astype(np.int32)
    # p2 extends p1's first full page -> a warm admission matches 8 tokens
    p2 = np.concatenate(
        [p1[:8], rng.integers(2, cfg.vocab_size, size=11).astype(np.int32)])

    def run(impl="gather", budget=None, cache=False):
        eng = ServingEngine(params, cfg.with_(paged_attn_impl=impl),
                            batch_size=2, max_seq=32, page_size=8,
                            backend="xla", max_prefill_tokens=budget,
                            prefix_cache=cache)
        outs = []
        for i, pr in enumerate((p1, p2)):    # sequential: p2 can hit p1's pages
            r = Request(uid=i, prompt=pr, max_tokens=3)
            eng.submit(r)
            eng.run_until_drained()
            outs.append(r.output)
        if cache:
            assert eng.stats.prefix_matched_tokens >= 8
        if budget is not None:
            # 12- and 19-token prompts under an 8-token budget must chunk
            assert eng.stats.prefill_batches > 2
        eng.pager.check_invariants()
        return outs

    cold = run()
    assert run(budget=8) == cold                           # mixed, oracle
    assert run(impl="pallas_interpret", budget=8) == cold  # mixed, kernel
    assert run(budget=8, cache=True) == cold               # warm chunks


def test_engine_mixed_overlap_decode_identity():
    """Decode steps interleaved *between* a long prompt's chunks (the mixed
    step: budgeted chunk rows + all decoding slots in one plan) leave every
    request's greedy output identical to the stop-the-world run."""
    cfg = get_config("codellama-7b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    lens = (5, 20, 9, 24)

    def run(budget):
        reqs = [Request(uid=i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            size=lens[i % 4]).astype(np.int32),
                        max_tokens=6)
                for i in range(5)]
        eng = ServingEngine(params, cfg, batch_size=3, max_seq=32, page_size=8,
                            backend="xla", max_prefill_tokens=budget)
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert stats.completed == len(reqs)
        eng.pager.check_invariants()
        return [r.output for r in reqs], stats

    rng = np.random.default_rng(7)
    base, _ = run(None)
    rng = np.random.default_rng(7)
    mixed, st = run(8)
    assert mixed == base
    # chunking actually happened: more prefill launches than stop-the-world
    assert st.prefill_batches > 3


# ------------------------------------------------------------ regressions ---
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("codellama-7b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_run_until_drained_raises_at_max_steps(setup):
    """Regression: hitting ``max_steps`` with work still pending used to
    ``break`` silently, handing back truncated outputs that looked complete
    (stats said fewer completions, but nothing failed loudly)."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=32, page_size=8,
                        backend="xla")
    eng.submit(Request(uid=7, prompt=np.arange(2, 8).astype(np.int32),
                       max_tokens=8))
    with pytest.raises(RuntimeError, match="max_steps=2"):
        eng.run_until_drained(max_steps=2)
    # the unfinished request is still live, not silently dropped
    assert any(s is not None for s in eng.slots) or eng.queue


def test_pages_evicted_synced_on_chunk_only_step(setup):
    """Regression: ``stats.pages_evicted`` was synced only after a decode
    launch, so a step that admits (evicting cached pages for the allocation)
    and runs a non-final chunk — nothing decodable yet — returned with the
    counter stale."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=16, page_size=8,
                        num_pages=3, backend="xla", prefix_cache=True,
                        max_prefill_tokens=8)
    r1 = Request(uid=0, prompt=np.arange(2, 10).astype(np.int32), max_tokens=1)
    eng.submit(r1)
    eng.run_until_drained()
    assert eng.stats.pages_inserted > 0 and eng.stats.pages_evicted == 0
    # different tokens -> no cache credit; 2 pages needed, 1 free: the alloc
    # must evict r1's cached page during admission
    r2 = Request(uid=1, prompt=np.arange(50, 62).astype(np.int32), max_tokens=1)
    eng.submit(r2)
    worked = eng.step()     # admit + first (non-final) chunk, no decode rows
    assert worked > 0
    assert eng.stats.pages_evicted > 0      # synced on the chunk-only return
    eng.run_until_drained()
    assert eng.stats.completed == 2
