"""Request lifecycle + deterministic fault injection: submit validation and
backpressure, cancel/deadline semantics across every phase (queued,
prefilling, decoding, swapped), FaultPlan determinism, bounded-retry recovery
with greedy token-identity under every injection site, swap-corruption
detection → re-prefill, and the non-strict engine's quarantine / degraded
drain (fail one request, keep serving the rest)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serving import kv_cache as KV
from repro.serving.engine import RejectedRequest, Request, ServingEngine
from repro.serving.faults import FaultPlan, FaultSpec, TransientFault


# ----------------------------------------------------------- plan (pure) ----
def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec("bogus", step=1)
    with pytest.raises(ValueError, match="no firing"):
        FaultSpec("page_alloc")
    with pytest.raises(ValueError, match="every"):
        FaultSpec("page_alloc", every=0)


def test_fault_plan_deterministic_and_budgeted():
    def mk():
        return FaultPlan([FaultSpec("page_alloc", prob=0.5, times=3),
                          FaultSpec("page_grow", every=2, times=None)],
                         seed=7)

    a, b = mk(), mk()
    for step in range(4):
        a.begin_step(step)
        b.begin_step(step)
        for _ in range(10):
            assert a.fires("page_alloc") == b.fires("page_alloc")
            assert a.fires("page_grow") == b.fires("page_grow")
    # Bernoulli site consumed its budget exactly; the log is diffable
    assert a.injected["page_alloc"] == 3
    assert a.log == b.log and len(a.log) > 3
    # unlimited periodic site fires on every 2nd probe (ops 0, 2, ..., 38)
    assert a.injected["page_grow"] == 20


def test_pool_pressure_is_windowed_condition():
    plan = FaultPlan([FaultSpec("pool_pressure", step=2, value=3, duration=2)])
    for step, want in ((1, 0), (2, 3), (3, 3), (4, 0)):
        plan.begin_step(step)
        assert plan.pressure_pages() == want
    # a polled condition, not an event: no budget or RNG consumed
    assert plan.total_injected == 0


# ----------------------------------------------------------------- setup ----
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("codellama-7b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, n=6, max_tokens=8, seed=5):
    # a shared one-page stem makes the prefix cache hit once early finishers
    # insert their pages — so prefix_evict faults have something to evict
    rng = np.random.default_rng(seed)
    lens = (3, 7, 10, 5)
    stem = rng.integers(2, cfg.vocab_size, 4).astype(np.int32)
    return [Request(uid=i,
                    prompt=np.concatenate(
                        [stem,
                         rng.integers(2, cfg.vocab_size,
                                      lens[i % 4]).astype(np.int32)]),
                    max_tokens=max_tokens)
            for i in range(n)]


def _drive(params, cfg, fault_plan=None, **kw):
    """Tight-pool engine (preemption + chunking + prefix cache all active)
    over the standard mixed workload; returns (engine, requests, stats)."""
    defaults = dict(batch_size=3, max_seq=24, page_size=4, num_pages=1 + 7,
                    backend="xla", max_prefill_tokens=8, prefix_cache=True)
    defaults.update(kw)
    eng = ServingEngine(params, cfg, fault_plan=fault_plan, **defaults)
    reqs = _reqs(cfg)
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained(max_steps=600)
    return eng, reqs, stats


@pytest.fixture(scope="module")
def ref_outputs(setup):
    cfg, params = setup
    _, reqs, _ = _drive(params, cfg)
    return [r.output for r in reqs]


# -------------------------------------------------- submit / backpressure ---
def test_submit_rejects_invalid_requests(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=16, backend="xla")
    empty = Request(uid=1, prompt=np.asarray([], np.int32), max_tokens=4)
    with pytest.raises(RejectedRequest, match="empty prompt"):
        eng.submit(empty)
    zero = Request(uid=2, prompt=np.arange(2, 6).astype(np.int32),
                   max_tokens=0)
    with pytest.raises(RejectedRequest, match="max_tokens"):
        eng.submit(zero)
    # structured terminal state even though submit raised
    for r in (empty, zero):
        assert r.finish_reason == "rejected" and r.error and r.done_t
    assert eng.stats.rejected == 2
    assert not eng.queue
    # RejectedRequest is a ValueError: pre-existing callers keep working
    assert issubclass(RejectedRequest, ValueError)


def test_submit_backpressure_bounded_queue(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=16, backend="xla",
                        max_queue=2)
    reqs = _reqs(cfg, n=3, max_tokens=2)
    assert eng.submit(reqs[0]) and eng.submit(reqs[1])
    # a full queue sheds load without raising — operational, not a bug
    assert eng.submit(reqs[2]) is False
    assert reqs[2].finish_reason == "rejected"
    assert "queue full" in reqs[2].error
    assert eng.stats.rejected == 1 and len(eng.queue) == 2
    stats = eng.run_until_drained()
    assert stats.completed == 2


# ------------------------------------------------------ cancel / deadline ---
def test_cancel_queued_and_active_and_unknown(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=32, backend="xla")
    active, queued = _reqs(cfg, n=2, max_tokens=30)
    eng.submit(active)
    eng.submit(queued)
    assert eng.cancel(queued.uid)              # still waiting in the queue
    assert queued.finish_reason == "cancelled" and queued.done_t
    eng.step()
    eng.step()                                 # active is mid-decode now
    n_out = len(active.output)
    assert eng.cancel(active.uid)              # decoding in a slot
    assert active.finish_reason == "cancelled"
    assert len(active.output) == n_out         # generated tokens survive
    assert not eng.cancel(999)                 # unknown uid
    assert not eng.cancel(active.uid)          # already terminal
    assert eng.stats.cancelled == 2
    assert eng.pager.free_pages == eng.pager.num_pages - 1
    eng.pager.check_invariants()


def test_cancel_swapped_request(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=3, max_seq=24, page_size=4,
                        num_pages=1 + 7, backend="xla")
    reqs = _reqs(cfg)
    for r in reqs:
        eng.submit(r)
    for _ in range(300):
        eng.step()
        if eng._swapped:
            break
    assert eng._swapped, "workload never preempted — test sizing broke"
    seq = next(iter(eng._swapped))
    victim = next(r for r in eng.queue if r.submit_seq == seq)
    assert eng.cancel(victim.uid)
    # the swap image is gone and its kept-page holds released immediately
    assert victim.finish_reason == "cancelled"
    assert seq not in eng._swapped
    eng.pager.check_invariants()
    eng.run_until_drained(max_steps=600)
    assert all(r.finish_reason in ("completed", "length", "cancelled")
               for r in reqs)
    assert eng.pager.free_pages == eng.pager.num_pages - 1


def test_deadline_expiry_queued_and_mid_decode(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=32, backend="xla")
    t = [0.0]
    eng._clock = lambda: t[0]
    r1 = Request(uid=1, prompt=np.arange(2, 8).astype(np.int32),
                 max_tokens=30, deadline_s=5.0)
    r2 = Request(uid=2, prompt=np.arange(2, 8).astype(np.int32),
                 max_tokens=4, ttft_deadline_s=3.0)
    eng.submit(r1)
    eng.submit(r2)
    eng.step()                   # r1 occupies the only slot; r2 waits
    t[0] = 4.0                   # r2 blows its TTFT budget while queued
    eng.step()
    assert r2.finish_reason == "deadline" and r2.first_token_t is None
    assert r1.finish_reason is None
    t[0] = 6.0                   # r1 blows its total budget mid-decode
    eng.step()
    assert r1.finish_reason == "deadline"
    assert len(r1.output) > 0    # partial output survives expiry
    assert eng.stats.expired == 2
    assert eng.pager.free_pages == eng.pager.num_pages - 1
    eng.pager.check_invariants()


def test_deadline_expiry_mid_prefill(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=64, page_size=8,
                        backend="xla", max_prefill_tokens=8)
    t = [0.0]
    eng._clock = lambda: t[0]
    r = Request(uid=9, prompt=np.arange(2, 40).astype(np.int32),
                max_tokens=4, deadline_s=1.0)
    eng.submit(r)
    eng.step()                   # one 8-token chunk lands
    assert 0 < int(eng.pos[0]) < len(r.prompt)
    t[0] = 2.0
    eng.step()                   # expires while still prefilling
    assert r.finish_reason == "deadline" and r.first_token_t is None
    assert eng.stats.expired == 1
    assert eng.pager.free_pages == eng.pager.num_pages - 1
    eng.pager.check_invariants()


# ------------------------------------------- fault recovery: token identity -
@pytest.mark.parametrize("spec", [
    FaultSpec("page_alloc", every=3, times=4),
    FaultSpec("page_grow", op=1, times=2),
    FaultSpec("prefix_evict", op=0, times=2),
    FaultSpec("decode_launch", step=3, times=2),
    FaultSpec("prefill_launch", op=1, times=1),
    FaultSpec("swap_drain", op=0, times=2),
    FaultSpec("pool_pressure", step=2, value=2, duration=2),
], ids=lambda s: s.site)
def test_injected_fault_greedy_identity(setup, ref_outputs, spec):
    """Every injection site degrades through retries / requeues / cold
    prefills — never through different tokens: the faulted run must complete
    every request with outputs identical to the no-fault run."""
    cfg, params = setup
    plan = FaultPlan([spec], seed=1)
    eng, reqs, stats = _drive(params, cfg, fault_plan=plan)
    if spec.site == "pool_pressure":
        # a condition, not an event: prove the window was actually seen
        assert plan.pressure_hits > 0, "pressure window never polled"
    else:
        assert plan.total_injected > 0, f"{spec.site} never fired"
    assert stats.completed == len(reqs)
    assert stats.faults_injected == plan.total_injected
    assert [r.output for r in reqs] == ref_outputs
    assert all(r.finish_reason in ("completed", "length") for r in reqs)
    eng.pager.check_invariants()


def test_swap_corruption_detected_and_reprefilled(setup, ref_outputs):
    """A corrupted host swap image must be *detected* (checksum mismatch at
    swap-in) and the victim re-prefilled from tokens — greedy outputs stay
    identical; resuming the poisoned rows would silently corrupt them."""
    cfg, params = setup
    plan = FaultPlan([FaultSpec("swap_corrupt", op=0, times=1)], seed=1)
    eng, reqs, stats = _drive(params, cfg, fault_plan=plan)
    assert plan.injected["swap_corrupt"] == 1
    assert stats.retries >= 1
    assert sum(r.reprefills for r in reqs) == 1
    assert stats.completed == len(reqs)
    assert [r.output for r in reqs] == ref_outputs
    eng.pager.check_invariants()


def test_chaos_run_deterministic(setup):
    """Same plan + seed + workload → byte-identical fault log and outputs:
    a chaos regression is a diffable event, not a flake."""
    cfg, params = setup

    def run():
        plan = FaultPlan([FaultSpec("page_alloc", every=7, times=2),
                          FaultSpec("page_grow", prob=0.2, times=2),
                          FaultSpec("decode_launch", step=4, times=1)],
                         seed=3)
        _, reqs, _ = _drive(params, cfg, fault_plan=plan)
        return plan.log, [r.output for r in reqs]

    log_a, out_a = run()
    log_b, out_b = run()
    assert log_a == log_b and len(log_a) > 0
    assert out_a == out_b


def test_decode_growth_retry_exhaustion_fails_request(setup):
    """A grow fault that never stops firing must drive the victim to a
    terminal ``failed`` on its bounded budget — not livelock the drain."""
    cfg, params = setup
    # op 0 is the admission grow; fault every decode-growth attempt after
    plan = FaultPlan([FaultSpec("page_grow", op=i, times=1)
                      for i in range(1, 9)])
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=32, page_size=4,
                        backend="xla", fault_plan=plan, retry_budget=2)
    r = Request(uid=5, prompt=np.arange(2, 6).astype(np.int32), max_tokens=20)
    eng.submit(r)
    stats = eng.run_until_drained(max_steps=100)
    assert r.finish_reason == "failed" and "budget" in r.error
    assert stats.failed == 1
    assert stats.retries == 3 and r.retries == 3   # budget + the final straw
    assert eng.pager.free_pages == eng.pager.num_pages - 1
    eng.pager.check_invariants()


# ------------------------------------------------- quarantine vs strict -----
def _forge_write_hazard(eng):
    """Ghost-list the write-cursor page of slot 0 in idle slot 2, keeping
    refcounts self-consistent — exactly the shared-page write hazard the
    tripwires exist for.  Callers pick prompt lengths that leave slot 0's
    position mid-page, so the cursor sits on an owned page."""
    pg = int(eng.pager.table()[0, int(eng.pos[0]) // eng.PS])
    assert pg != KV.TRASH_PAGE
    eng.pager._table[2, 0] = pg
    eng.pager._slot_pages[2].append(pg)
    eng.pager._ref[pg] += 1


def _hazard_pair():
    # 6- and 9-token prompts: positions 7 and 10 after the prefill sample,
    # both mid-page at page_size=4 (a page-aligned position would put the
    # cursor on a not-yet-grown page instead of an owned one)
    return (Request(uid=1, prompt=np.arange(2, 8).astype(np.int32),
                    max_tokens=6),
            Request(uid=2, prompt=np.arange(2, 11).astype(np.int32),
                    max_tokens=6))


def test_strict_invariant_violation_still_raises(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=3, max_seq=16, page_size=4,
                        backend="xla")                       # strict default
    for r in _hazard_pair():
        eng.submit(r)
    eng.step()
    _forge_write_hazard(eng)
    with pytest.raises(KV.PagerInvariantError, match="write hazard"):
        eng.step()


def test_nonstrict_quarantines_offending_slot_keeps_serving(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=3, max_seq=16, page_size=4,
                        backend="xla", strict=False)
    r1, r2 = _hazard_pair()
    eng.submit(r1)
    eng.submit(r2)
    eng.step()
    _forge_write_hazard(eng)
    eng.step()                    # tripwire fires → slot 0 quarantined
    assert r1.finish_reason == "failed" and "hazard" in r1.error
    assert eng.stats.failed == 1
    eng.run_until_drained()       # ...and the engine keeps serving r2
    assert r2.finish_reason in ("completed", "length")
    eng.pager.free_slot(2)        # undo the forged ghost listing
    eng.pager.check_invariants()
    assert eng.pager.free_pages == eng.pager.num_pages - 1


def test_nonstrict_stall_fails_head_keeps_serving(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=16, page_size=4,
                        num_pages=9, backend="xla", strict=False)
    eng.pager._free = eng.pager._free[:1]      # simulate a page leak: 1 left
    big = Request(uid=42, prompt=np.arange(2, 9).astype(np.int32),
                  max_tokens=2)                # needs 2 pages: unadmittable
    small = Request(uid=43, prompt=np.arange(2, 4).astype(np.int32),
                    max_tokens=2)              # fits in the surviving page
    eng.submit(big)
    eng.submit(small)
    stats = eng.run_until_drained()
    assert big.finish_reason == "failed"
    assert "admission stalled" in big.error and "uid=42" in big.error
    assert small.finish_reason in ("completed", "length")
    assert stats.failed == 1 and stats.completed == 1


def test_stall_and_max_steps_errors_name_every_pending_request(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=16, page_size=4,
                        num_pages=9, backend="xla")
    eng.pager._free = eng.pager._free[:1]
    eng.submit(Request(uid=42, prompt=np.arange(2, 9).astype(np.int32),
                       max_tokens=2))
    eng.submit(Request(uid=77, prompt=np.arange(2, 9).astype(np.int32),
                       max_tokens=2))
    with pytest.raises(RuntimeError) as ei:
        eng.run_until_drained()
    msg = str(ei.value)
    for needle in ("uid=42", "uid=77", "phase=queued", "pager: free="):
        assert needle in msg, f"stall report missing {needle!r}:\n{msg}"
    # the max_steps ceiling carries the same full report
    eng2 = ServingEngine(params, cfg, batch_size=2, max_seq=16, page_size=4,
                         backend="xla")
    eng2.submit(Request(uid=7, prompt=np.arange(2, 6).astype(np.int32),
                        max_tokens=2))
    with pytest.raises(RuntimeError) as ei2:
        eng2.run_until_drained(max_steps=0)
    msg2 = str(ei2.value)
    assert "uid=7" in msg2 and "phase=queued" in msg2 and "pager:" in msg2
