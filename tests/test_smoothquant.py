"""SmoothQuant+ pipeline tests: calibration stats, smoothing equivalence
(the paper's eq. 5 must hold EXACTLY, modulo bf16 rounding), alpha search,
and end-to-end PTQ accuracy ordering (SQ+ <= RTN loss)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import QuantConfig
from repro.core import apply as AP
from repro.core import calibration as C
from repro.core import search as SE
from repro.core import smoothing as SM
from repro.models import api

B, T = 1, 24
# run the full matrix on a representative subset (one per family)
FAMILIES = [
    "codellama-7b",        # dense (paper's model)
    "starcoder2-15b",      # gelu/layernorm/bias
    "granite-moe-1b-a400m",# moe
    "deepseek-v2-236b",    # mla + moe
    "zamba2-7b",           # hybrid
    "rwkv6-7b",            # rwkv
    "whisper-medium",      # enc-dec
]


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    # f32 params so equivalence checks aren't drowned in bf16 rounding
    cfg = cfg.with_(dtype="float32")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    batches = C.synthetic_calibration_set(cfg, n_seqs=2, seq_len=T)
    return cfg, params, batches


@pytest.fixture(scope="module", params=FAMILIES)
def setup(request):
    cfg, params, batches = _setup(request.param)
    col = C.collect_stats(params, cfg, batches)
    return cfg, params, batches, col


def test_stats_cover_all_groups(setup):
    cfg, params, batches, col = setup
    for g in SM.smoothing_groups(cfg):
        try:
            st = SM.assemble_stats(col, g.stats_block, g.stats_sub)
        except KeyError:
            pytest.fail(f"no stats for group {g.name}")
        assert np.all(st >= 0) and np.isfinite(st).all()


def test_smoothing_is_mathematically_equivalent(setup):
    """Paper eq. 5: smoothed (unquantized) model output == original."""
    cfg, params, batches, col = setup
    smoothed, s_map = SM.smooth_model(params, cfg, col, alpha=0.5)
    assert s_map, "no groups smoothed"
    batch = batches[0]
    ref = api.forward_fn(params, batch, cfg, backend="xla").astype(jnp.float32)
    got = api.forward_fn(smoothed, batch, cfg, backend="xla").astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=2e-3, atol=2e-3
    )


def test_alpha_search_returns_grid_min(setup):
    cfg, params, batches, col = setup
    res = SE.search_alpha(params, cfg, col, step=0.25, group_size=16)
    assert set(res.losses) == {0.0, 0.25, 0.5, 0.75, 1.0}
    assert res.loss == min(res.losses.values())
    assert np.isfinite(res.loss)


def test_sqplus_loss_not_worse_than_rtn(setup):
    """Smoothing at the searched alpha must not increase the weighted quant
    loss vs no smoothing (alpha=0 ≈ weight-only scaling; the paper's claim)."""
    cfg, params, batches, col = setup
    res = SE.search_alpha(params, cfg, col, step=0.25, group_size=16)
    base = SE.model_quant_loss(params, cfg, col, 0.0, group_size=16)
    assert res.loss <= base * (1 + 1e-6)


def test_end_to_end_ptq_runs_and_shrinks(setup):
    cfg, params, batches, col = setup
    qp, rep = AP.smoothquant_plus(
        params, cfg, batches, QuantConfig(group_size=16), step=0.5
    )
    assert rep.quantized_paths, "nothing quantized"
    # smoke scale uses group_size=16 + f32 scales → ~0.5×; production
    # (group=128, bf16) hits ~0.27× (asserted in test_quantize)
    assert rep.quant_bytes < 0.6 * rep.fp_bytes
    batch = batches[0]
    logits = api.forward_fn(qp, batch, cfg, backend="xla")
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_quantized_model_bounded_error(setup):
    """W4 output must stay within a bounded relative error of FP.

    NOTE: random-init smoke models have NO activation-outlier structure, so
    SQ+ ≈ RTN here; the paper's advantage is reproduced mechanistically in
    test_sqplus_beats_rtn_with_outlier_channels below."""
    cfg, params, batches, col = setup
    qp, rep = AP.smoothquant_plus(
        params, cfg, batches, QuantConfig(group_size=16), step=0.5
    )
    batch = batches[0]
    ref = np.asarray(api.forward_fn(params, batch, cfg, backend="xla"), np.float32)
    got = np.asarray(api.forward_fn(qp, batch, cfg, backend="xla"), np.float32)
    rel = np.linalg.norm(got - ref) / max(np.linalg.norm(ref), 1e-9)
    assert np.isfinite(got).all() and rel < 0.6, f"rel error {rel:.3f}"


def test_sqplus_beats_rtn_with_outlier_channels():
    """The paper's core mechanism: when activations have persistent per-
    channel outliers (the >6.7B-LLM regime, §2.2), smoothing before RTN must
    reduce the quantized model's output error vs plain RTN.

    We induce the outlier structure by scaling a few embedding channels ×40:
    every token then carries those hot channels down the residual stream,
    exactly the 'fixed channels across all tokens' pattern of Fig. 2."""
    cfg = get_config("codellama-7b", smoke=True).with_(dtype="float32")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    hot = np.zeros(cfg.d_model, np.float32) + 1.0
    hot[[7, 13, 21, 40]] = 40.0
    params["embed"]["table"] = params["embed"]["table"] * hot[None, :]
    batches = C.synthetic_calibration_set(cfg, n_seqs=2, seq_len=24)

    qp, rep = AP.smoothquant_plus(
        params, cfg, batches, QuantConfig(group_size=16), step=0.25
    )
    rtn = AP.rtn_baseline(params, cfg, QuantConfig(group_size=16))
    b = batches[0]
    ref = np.asarray(api.forward_fn(params, b, cfg, backend="xla"), np.float32)
    sq = np.asarray(api.forward_fn(qp, b, cfg, backend="xla"), np.float32)
    rt = np.asarray(api.forward_fn(rtn, b, cfg, backend="xla"), np.float32)
    err_sq = np.linalg.norm(sq - ref) / np.linalg.norm(ref)
    err_rt = np.linalg.norm(rt - ref) / np.linalg.norm(ref)
    assert err_sq < err_rt, (
        f"SmoothQuant+ ({err_sq:.4f}) must beat RTN ({err_rt:.4f}) "
        "in the outlier regime"
    )
    # and the searched alpha should be > 0 (it found smoothing useful)
    assert rep.alpha > 0.0
