"""Shared-prefix KV cache: block-hash chaining, refcounted sharing + LRU
eviction at the pager level, suffix-only admission planning, and engine-level
cache-hit-vs-cold greedy token identity (fp16 and int8 pools, GQA and MLA)."""
from collections import deque

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serving import kv_cache as KV
from repro.serving.engine import Request, ServingEngine
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import Scheduler


# ------------------------------------------------------------ hash index ----
def test_block_hash_chaining_and_mode_isolation():
    pool = KV.PagePool(9, 4, batch_size=1, max_pages_per_slot=8)
    c = PrefixCache(pool, 4, mode="fp")
    toks = np.arange(12)
    h = c.block_hashes(toks)
    assert len(h) == 3                      # full pages only
    assert c.block_hashes(np.arange(11))[:2] == h[:2]       # shared prefix
    # chaining: same page-2 tokens behind a different page 1 → different hash
    other = np.concatenate([np.arange(4), np.zeros(4, int), np.arange(8, 12)])
    assert c.block_hashes(other)[2] != h[2]
    # kv-quant mode is folded into the root: int8 pools never cross-match fp
    c8 = PrefixCache(KV.PagePool(9, 4, 1, 8), 4, mode="int8")
    assert c8.block_hashes(toks) != h


def test_match_attach_cow_and_lru_eviction():
    pool = KV.PagePool(10, 4, batch_size=3, max_pages_per_slot=9)
    cache = PrefixCache(pool, 4, mode="")
    toks = np.arange(9)                     # 2 full pages + 1 tail token
    pages = pool.alloc(0, 3)
    cache.insert(toks, pages, 2)
    assert len(cache) == 2 and pool.is_cached(pages[0])
    # a hit attaches shared read-only pages: refcount 2, still cached
    got, mtok = cache.match(toks)
    assert got == pages[:2] and mtok == 8
    pool.attach(1, got)
    pool.check_invariants()
    assert pool.page_ref(pages[0]) == 2
    # COW hands slot 1 a private copy and releases the shared one
    old, new = pool.cow(1, 1)
    assert old == pages[1] and pool.page_ref(new) == 1
    assert not pool.is_cached(new) and pool.page_ref(pages[1]) == 1
    pool.check_invariants()
    # owner finishes: cached pages become evictable, uncached pages free
    pool.free_slot(0)
    pool.free_slot(1)
    pool.check_invariants()
    assert cache.evictable_count() == 2
    free0 = pool.free_pages
    assert pool.can_alloc(free0 + 2)        # evictable counts as allocatable
    # allocation pressure reclaims LRU-first and drops the index entries:
    # pages[1] became unreferenced before pages[0] (slot 1 still shared the
    # latter), so it is the LRU victim and the chain now ends after page 0
    pool.alloc(2, free0 + 1)
    pool.check_invariants()
    assert cache.stats.evicted_pages == 1 and len(cache) == 1
    got, mtok = cache.match(toks)
    assert got == [pages[0]] and mtok == 4


def test_swap_holds_keep_shared_pages_resident():
    pool = KV.PagePool(12, 4, batch_size=2, max_pages_per_slot=6)
    cache = PrefixCache(pool, 4, mode="")
    toks = np.arange(8)
    pages = pool.alloc(0, 3)                # 2 full (cached) + 1 private tail
    cache.insert(toks, pages, 2)
    kept, private = pool.split_for_swap(0)
    assert [p for _, p in kept] == pages[:2]
    assert [li for li, _ in private] == [2]
    pool.swap_out(0)
    pool.check_invariants()
    # held pages are pinned: not evictable, not freeable
    assert cache.evictable_count() == 0
    assert pool.page_ref(pages[0]) == 1
    # swap-in re-acquires the held pages and reallocs the private one
    fresh = pool.swap_in(1, kept, [2])
    pool.check_invariants()
    assert len(fresh) == 1
    assert pool.slot_pages(1)[:2] == pages[:2]


def test_assert_live_tables_validates_refcounts():
    pool = KV.PagePool(9, 4, batch_size=2, max_pages_per_slot=4)
    cache = PrefixCache(pool, 4, mode="")
    pages = pool.alloc(0, 2)
    write_pos = np.asarray([6, 0])
    ok = dict(refs=pool.refs(), held=pool.held(), cached=pool.cached_mask())
    KV.assert_live_tables(pool.table(), write_pos, 4, [True, False], **ok)
    # sharing the write page (without COW) must trip the write hazard
    pool.attach(1, [pages[1]])
    with pytest.raises(RuntimeError, match="copy-on-write"):
        KV.assert_live_tables(pool.table(), write_pos, 4, [True, False],
                              refs=pool.refs(), held=pool.held(),
                              cached=pool.cached_mask())
    pool.free_slot(1)
    # a cached (read-only) write page trips it too
    cache.insert(np.arange(8), pages, 2)
    with pytest.raises(RuntimeError, match="copy-on-write"):
        KV.assert_live_tables(pool.table(), write_pos, 4, [True, False],
                              refs=pool.refs(), held=pool.held(),
                              cached=pool.cached_mask())
    # ...but not while the cursor sits in an uncached private page
    pool.grow(0, 1)
    KV.assert_live_tables(pool.table(), np.asarray([9, 0]), 4, [True, False],
                          refs=pool.refs(), held=pool.held(),
                          cached=pool.cached_mask())
    # refcount drift (corruption) is named
    pool.refs()[pages[0]] += 1
    with pytest.raises(RuntimeError, match="refcount out of sync"):
        KV.assert_live_tables(pool.table(), np.asarray([9, 0]), 4,
                              [True, False], refs=pool.refs())
    pool.refs()[pages[0]] -= 1


def test_scheduler_charges_only_the_uncached_suffix():
    pool = KV.PagePool(33, 4, batch_size=4, max_pages_per_slot=8)
    cache = PrefixCache(pool, 4, mode="")
    sched = Scheduler(page_size=4, max_seq=32)
    sys_p = np.arange(2, 14)                              # 12 tokens, 3 pages
    donor_pages = pool.alloc(3, 4)
    cache.insert(sys_p, donor_pages, 3)
    pool.free_slot(3)
    req = Request(uid=0, prompt=np.concatenate([sys_p, [77, 78]]),
                  max_tokens=8)                           # 14 tokens
    free0 = pool.free_pages
    [bkt] = sched.plan(deque([req]), [0], pool, cache=cache)
    assert bkt.prefix_lens == [12] and bkt.pad_len == 4   # suffix bucket
    assert bkt.shared == [3] and bkt.cow == [None]
    # charged only ceil((14+1)/4) - 3 = 1 fresh page; 3 pages attached shared
    assert bkt.needs == [1] and free0 - pool.free_pages == 1
    assert pool.slot_pages(0)[:3] == donor_pages[:3]
    pool.check_invariants()
    # page-aligned full match: COW of the last matched page + 1-token suffix
    pool.free_slot(0)
    req2 = Request(uid=1, prompt=sys_p.copy(), max_tokens=8)
    free0 = pool.free_pages
    [bkt2] = sched.plan(deque([req2]), [1], pool, cache=cache)
    assert bkt2.prefix_lens == [11] and bkt2.cow[0] is not None
    assert bkt2.needs == [2]                              # COW copy + tail
    src, dst = bkt2.cow[0]
    assert src == donor_pages[2] and pool.slot_pages(1)[2] == dst
    assert free0 - pool.free_pages == 2
    pool.check_invariants()
    # the plan leaves a hold pinning the COW source until the engine has
    # copied its rows — only then may it become evictable/reallocatable
    assert pool.held()[src] == 1 and pool.page_ref(src) == 1
    assert not pool.can_alloc(pool.free_pages + 1)   # src not reclaimable
    pool.drop_hold(src)
    pool.check_invariants()
    assert pool.can_alloc(pool.free_pages + 1)       # now evictable again


def test_plan_blocks_when_only_evictable_pages_are_the_match():
    """Regression: the admission check must not count the head's own matched
    evictable pages as allocatable headroom — attaching pins them, so a pool
    whose only reclaimable pages are the match itself cannot supply the
    fresh pages and the head must block gracefully (FCFS), not crash plan()
    with an out-of-pages RuntimeError mid-admission."""
    pool = KV.PagePool(5, 4, batch_size=2, max_pages_per_slot=4)
    cache = PrefixCache(pool, 4, mode="")
    sys_p = np.arange(2, 14)                      # 12 tokens = 3 full pages
    donor = pool.alloc(0, 4)
    cache.insert(sys_p, donor, 3)
    pool.free_slot(0)                             # 3 evictable + 1 free
    pool.alloc(1, 1)                              # free = 0, evictable = 3
    sched = Scheduler(page_size=4, max_seq=16)
    q = deque([Request(uid=0, prompt=np.concatenate([sys_p, [77, 78]]),
                       max_tokens=4)])
    assert sched.plan(q, [0], pool, cache=cache) == []
    assert len(q) == 1                            # head still queued, intact
    pool.check_invariants()
    assert cache.evictable_count() == 3           # nothing was pinned


# --------------------------------------------------- engine token identity --
@pytest.fixture(scope="module")
def gqa_setup():
    cfg = get_config("codellama-7b", smoke=True)
    return cfg, api.init_model(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def mla_setup():
    cfg = get_config("deepseek-v2-236b", smoke=True)
    return cfg, api.init_model(jax.random.PRNGKey(0), cfg)


def _prefix_prompts(cfg, sys_len=12, tails=(3, 5, 2), seed=7):
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(2, cfg.vocab_size, sys_len).astype(np.int32)
    return [np.concatenate(
        [sys_p, rng.integers(2, cfg.vocab_size, n).astype(np.int32)])
        for n in tails]


def _drive(params, cfg, prompts, prefix_cache, max_tokens=5, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_seq", 32)
    eng = ServingEngine(params, cfg, page_size=4, backend="xla",
                        prefix_cache=prefix_cache, **kw)
    reqs = [Request(uid=i, prompt=p.copy(), max_tokens=max_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    eng.pager.check_invariants()
    return [r.output for r in reqs], eng


def _identity_case(cfg, params):
    """Cold engine vs prefix-cache engine on prompts sharing a 12-token
    system prefix, then a warm probe on the cached engine: greedy outputs
    must be token-identical throughout, and the probe must prefill only its
    suffix (the acceptance criterion: prefilled_tokens down by ~L, at least
    L/page_size pages shared)."""
    prompts = _prefix_prompts(cfg)
    cold, _ = _drive(params, cfg, prompts, False)
    warm, eng = _drive(params, cfg, prompts, True)
    assert warm == cold
    assert eng.stats.prefix_hits >= 1       # later admissions matched
    # warm probe: identical prompt to request 0 (15 tokens, 3 full pages)
    before = eng.stats.prefilled_tokens
    shared0 = eng.stats.pages_shared
    probe = Request(uid=99, prompt=prompts[0].copy(), max_tokens=5)
    eng.submit(probe)
    eng.run_until_drained()
    eng.pager.check_invariants()
    assert probe.output == cold[0]
    L = len(prompts[0]) // 4 * 4            # whole-page prefix tokens
    assert eng.stats.prefilled_tokens - before == len(prompts[0]) - L
    assert eng.stats.pages_shared - shared0 >= L // 4
    assert eng.stats.prefix_matched_tokens >= L


def test_engine_cache_hit_token_identity_gqa_fp(gqa_setup):
    cfg, params = gqa_setup
    _identity_case(cfg, params)


def test_engine_cache_hit_token_identity_gqa_int8(gqa_setup):
    cfg, _ = gqa_setup
    cfg = cfg.with_(dtype="float32", kv_quant=True)
    _identity_case(cfg, api.init_model(jax.random.PRNGKey(0), cfg))


def test_engine_cache_hit_token_identity_mla_fp(mla_setup):
    cfg, params = mla_setup
    _identity_case(cfg, params)


def test_engine_cache_hit_token_identity_mla_int8(mla_setup):
    cfg, _ = mla_setup
    cfg = cfg.with_(dtype="float32", kv_quant=True)
    _identity_case(cfg, api.init_model(jax.random.PRNGKey(0), cfg))


def test_engine_full_aligned_match_takes_cow(gqa_setup):
    """A page-aligned fully-cached prompt re-prefills exactly one token into
    a copy-on-write duplicate of the final matched page — and still emits
    cold-identical greedy tokens."""
    cfg, params = gqa_setup
    rng = np.random.default_rng(3)
    p16 = rng.integers(2, cfg.vocab_size, 16).astype(np.int32)   # 4 pages
    cold, _ = _drive(params, cfg, [p16], False)
    _, eng = _drive(params, cfg, [p16], True)
    probe = Request(uid=9, prompt=p16.copy(), max_tokens=5)
    before = eng.stats.prefilled_tokens
    eng.submit(probe)
    eng.run_until_drained()
    eng.pager.check_invariants()
    assert probe.output == cold[0]
    assert eng.stats.cow_copies == 1
    assert eng.stats.prefilled_tokens - before == 1


def test_engine_preemption_with_cache_stays_identical(gqa_setup):
    """Pool pressure with the cache on: LRU eviction feeds allocation,
    preemption swaps only private pages (shared prefix pages stay resident
    under swap holds), and greedy outputs still match an unconstrained
    cache-off engine."""
    cfg, params = gqa_setup
    prompts = _prefix_prompts(cfg, sys_len=12, tails=(5, 5, 5, 5))
    cold, _ = _drive(params, cfg, prompts, False, max_tokens=10,
                     batch_size=3, max_seq=32)
    tight, eng = _drive(params, cfg, prompts, True, max_tokens=10,
                        batch_size=3, max_seq=32, num_pages=1 + 8)
    assert tight == cold
    assert eng.stats.preemptions > 0 and eng.stats.resumes == eng.stats.preemptions
    assert eng.stats.pages_evicted > 0       # cache gave pages back under load
    assert eng.stats.pages_shared > 0
