"""Pallas flash-attention kernel vs jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention, flash_vmem_bytes


def _naive(q, k, v, causal=True):
    b, t, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    grp = h // hkv
    kr = jnp.repeat(k, grp, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v, grp, axis=2).astype(jnp.float32)
    sc = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32), kr) * d**-0.5
    if causal:
        mask = jnp.tril(jnp.ones((t, s), bool), k=s - t)
        sc = jnp.where(mask[None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", p, vr).astype(q.dtype)


def _mk(b, t, s, h, hkv, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (s, b, hkv, d), jnp.float32).transpose(1, 0, 2, 3)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("b,t,h,hkv,d", [
    (1, 256, 2, 2, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA group 2
    (1, 512, 8, 2, 128),    # GQA group 4
    (1, 384, 2, 1, 64),     # t not multiple of block
])
def test_flash_matches_naive_causal(b, t, h, hkv, d):
    q, k, v = _mk(b, t, t, h, hkv, d)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_kv=128,
                          interpret=True)
    want = _naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_noncausal():
    q, k, v = _mk(1, 128, 256, 2, 2, 64, seed=3)
    got = flash_attention(q, k, v, causal=False, block_q=128, block_kv=128,
                          interpret=True)
    want = _naive(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_matches_chunked_attention_module():
    from repro.models.attention import chunked_attention

    b, t, h, hkv, d = 1, 256, 4, 2, 32
    q, k, v = _mk(b, t, t, h, hkv, d, seed=5)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    want = chunked_attention(q, k, v, pos, pos, causal=True)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-3, atol=5e-3)


@settings(max_examples=8, deadline=None)
@given(
    t_blocks=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    grp=st.sampled_from([1, 2]),
    d=st.sampled_from([32, 64]),
    seed=st.integers(0, 100),
)
def test_property_flash_allclose(t_blocks, h, grp, d, seed):
    t = t_blocks * 128
    hkv = max(1, h // grp)
    hq = hkv * grp
    q, k, v = _mk(1, t, t, hq, hkv, d, seed=seed)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_kv=128,
                          interpret=True)
    want = _naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_flash_vmem_budget():
    assert flash_vmem_bytes(512, 512, 128) < 4 * 2**20  # « 16 MB v5e VMEM


def test_flash_backend_end_to_end_model():
    """Whole-model forward with attn_impl=flash (interpret) vs chunked."""
    from repro.configs import get_config
    from repro.models import api

    cfg = get_config("codellama-7b", smoke=True).with_(dtype="float32")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 128), 0,
                              cfg.vocab_size, jnp.int32)
    base = api.forward_fn(params, {"tokens": toks}, cfg, backend="xla")
    cfg_f = cfg.with_(attn_impl="flash_interpret")
    got = api.forward_fn(params, {"tokens": toks}, cfg_f, backend="xla")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(base, np.float32),
                               rtol=5e-3, atol=5e-3)
