"""Paged KV cache: pager alloc/free invariants, page write/gather round trip,
scheduler bucketing, and end-to-end equivalence of the paged engine with a
monolithic-cache greedy reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serving import kv_cache as KV
from repro.serving.engine import Request, ServingEngine, UnsupportedModelError
from repro.serving.scheduler import Scheduler


# ------------------------------------------------------------------ pager ---
def test_pager_alloc_free_invariants():
    pool = KV.PagePool(num_pages=9, page_size=4, batch_size=3,
                       max_pages_per_slot=4)
    assert pool.free_pages == 8                    # page 0 reserved as trash
    a = pool.alloc(0, 3)
    b = pool.alloc(1, 2)
    pool.check_invariants()
    assert KV.TRASH_PAGE not in a + b
    assert set(a).isdisjoint(b)
    assert pool.free_pages == 3
    # table rows carry the allocation, trash-padded
    assert pool.table()[0, :3].tolist() == a
    assert (pool.table()[0, 3:] == KV.TRASH_PAGE).all()
    pool.free_slot(0)
    pool.check_invariants()
    assert pool.free_pages == 6
    assert (pool.table()[0] == KV.TRASH_PAGE).all()
    # freed pages are reusable by another slot
    c = pool.alloc(2, 4)
    pool.check_invariants()
    assert set(c).isdisjoint(pool.slot_pages(1))


def test_pager_rejects_double_alloc_and_exhaustion():
    pool = KV.PagePool(num_pages=5, page_size=4, batch_size=2,
                       max_pages_per_slot=4)
    pool.alloc(0, 2)
    with pytest.raises(RuntimeError):
        pool.alloc(0, 1)                           # slot already owns pages
    with pytest.raises(RuntimeError):
        pool.alloc(1, 3)                           # only 2 pages left
    assert pool.can_alloc(2) and not pool.can_alloc(3)
    pool.free_slot(0)
    pool.alloc(1, 4)
    pool.check_invariants()


def test_admit_decode_finish_cycles_conserve_pages():
    pool = KV.PagePool(num_pages=13, page_size=4, batch_size=4,
                       max_pages_per_slot=3)
    rng = np.random.default_rng(0)
    live = {}
    for step in range(200):
        slot = int(rng.integers(0, 4))
        if slot in live:
            pool.free_slot(slot)
            del live[slot]
        else:
            n = int(rng.integers(1, 4))
            if pool.can_alloc(n):
                live[slot] = pool.alloc(slot, n)
        pool.check_invariants()
    owned = [p for pages in live.values() for p in pages]
    assert len(owned) + pool.free_pages == pool.num_pages - 1


# --------------------------------------------------- write / gather round ---
def test_write_prefix_then_gather_recovers_tokens():
    ps, n_pages, pps = 4, 9, 3
    pool_host = KV.PagePool(n_pages, ps, batch_size=2, max_pages_per_slot=pps)
    pool_host.alloc(0, 3)
    pool_host.alloc(1, 2)
    lens = np.array([10, 6], np.int32)
    pad = 12
    kv = jax.random.normal(jax.random.PRNGKey(0), (2, 2, pad, 3), jnp.float32)
    pools = jnp.zeros((2, n_pages, ps, 3), jnp.float32)     # [L=2, NP, PS, D]
    page, off = KV.prefix_write_plan(lens, pool_host.table(), ps, pad)
    out = KV.write_prefix(pools, kv, jnp.asarray(page), jnp.asarray(off))
    for row in range(2):
        rows = KV.gather_pages(
            out[0], jnp.asarray(pool_host.table()))[row]    # layer 0
        got = np.asarray(rows[: lens[row]])
        np.testing.assert_array_equal(got, np.asarray(kv[0, row, : lens[row]]))
    # padding beyond each row's length went to the trash page, not its pages
    tail = KV.gather_pages(out[0], jnp.asarray(pool_host.table()))[1]
    assert np.asarray(tail[lens[1]: 8]).sum() == 0


# -------------------------------------------------------------- scheduler ---
def _req(uid, n, max_tokens=4):
    return Request(uid=uid, prompt=np.arange(2, 2 + n, dtype=np.int32),
                   max_tokens=max_tokens)


def test_scheduler_buckets_by_length_and_reserves_pages():
    from collections import deque
    pool = KV.PagePool(33, 4, batch_size=4, max_pages_per_slot=8)
    sched = Scheduler(page_size=4, max_seq=32)
    q = deque([_req(0, 3), _req(1, 4), _req(2, 9), _req(3, 2)])
    buckets = sched.plan(q, [0, 1, 2, 3], pool)
    assert not q
    by_len = {b.pad_len: b for b in buckets}
    # 3, 4, 2 → bucket 4; 9 → bucket 16
    assert sorted(by_len) == [4, 16]
    assert [r.uid for r in by_len[4].reqs] == [0, 1, 3]
    assert [r.uid for r in by_len[16].reqs] == [2]
    pool.check_invariants()
    assert pool.free_pages == 32 - sum(n for b in buckets for n in b.needs)


def test_scheduler_fcfs_blocks_on_page_pressure():
    from collections import deque
    pool = KV.PagePool(5, 4, batch_size=4, max_pages_per_slot=4)   # 4 free
    sched = Scheduler(page_size=4, max_seq=16)
    q = deque([_req(0, 12, max_tokens=4), _req(1, 2, max_tokens=2)])
    buckets = sched.plan(q, [0, 1, 2, 3], pool)
    # head needs 4 pages → admitted; next would need 1 but 0 remain → waits
    assert sum(len(b.reqs) for b in buckets) == 1
    assert len(q) == 1 and q[0].uid == 1


def test_scheduler_prefill_token_budget_chunks_backlog():
    from collections import deque
    pool = KV.PagePool(65, 4, batch_size=8, max_pages_per_slot=8)
    sched = Scheduler(page_size=4, max_seq=32, max_prefill_tokens=8)
    q = deque([_req(i, 4) for i in range(4)])
    buckets = sched.plan(q, list(range(8)), pool)
    # 4-token buckets, budget 8 → two requests this step, two wait
    assert sum(len(b.reqs) for b in buckets) == 2
    assert len(q) == 2


# ------------------------------------------------------------ end-to-end ----
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("codellama-7b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_ref(params, cfg, prompt, max_tokens, smax, eos=1):
    logits, cache = api.prefill_fn(
        params, {"tokens": jnp.asarray(prompt)[None]}, cfg, smax, backend="xla")
    out = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    while len(out) < max_tokens and out[-1] != eos and pos < smax - 1:
        lg, cache = api.decode_fn(
            params, {"token": jnp.asarray([[out[-1]]], jnp.int32),
                     "position": jnp.asarray([pos], jnp.int32)},
            cache, cfg, backend="xla")
        out.append(int(jnp.argmax(lg, -1)[0]))
        pos += 1
    return out


def test_paged_engine_matches_monolithic_greedy(setup):
    """Acceptance: mixed-length 7-request queue, batch_size=3, paged engine
    outputs token-identical to the monolithic-cache greedy reference."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    lens = (5, 9, 7, 12)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        size=lens[i % 4]).astype(np.int32),
                    max_tokens=6)
            for i in range(7)]
    eng = ServingEngine(params, cfg, batch_size=3, max_seq=48, page_size=8,
                        backend="xla")
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == 7
    # joint prefill actually batched: fewer launches than requests
    assert stats.prefill_batches < 7
    for r in reqs:
        assert r.output == _greedy_ref(params, cfg, r.prompt, r.max_tokens, 48)
    eng.pager.check_invariants()
    assert eng.pager.free_pages == eng.pager.num_pages - 1   # all reclaimed


def test_paged_engine_bucket_padding_is_harmless(setup):
    """A prompt whose length is far off the bucket boundary must sample its
    first token from the true last position, not the padded one."""
    cfg, params = setup
    prompt = np.arange(3, 8).astype(np.int32)            # len 5 → bucket 8
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=32, page_size=8,
                        backend="xla")
    req = Request(uid=0, prompt=prompt, max_tokens=4)
    eng.submit(req)
    eng.run_until_drained()
    assert req.output == _greedy_ref(params, cfg, prompt, 4, 32)


def test_paged_engine_page_pressure_defers_admission(setup):
    """With pages for only ~one request, the engine must still drain the
    queue by recycling pages between requests."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=3, max_seq=32, page_size=8,
                        num_pages=1 + 4, backend="xla")    # one slot's worth
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(2, cfg.vocab_size, 6).astype(np.int32),
                    max_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == 3
    eng.pager.check_invariants()


def test_paged_engine_rejects_oversized_prompt(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch_size=1, max_seq=16, backend="xla")
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.zeros(16, np.int32), max_tokens=2))


def test_paged_engine_mla_smoke():
    """Paged decode also covers the MLA latent cache (deepseek family)."""
    cfg = get_config("deepseek-v2-236b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=32, page_size=8,
                        backend="xla")
    reqs = [Request(uid=i, prompt=rng.integers(2, cfg.vocab_size,
                                               size=(5, 9)[i % 2]).astype(np.int32),
                    max_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == 3
    for r in reqs:
        assert r.output == _greedy_ref(params, cfg, r.prompt, r.max_tokens, 32)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_int8_prefill_slab_matches_paged_admission(dtype):
    """ROADMAP closeout: the *contiguous* prefill slab now quantizes per-row
    (codes + f32 scale rows, matching the page pools' layout) instead of
    casting — bit-for-bit the same int8 codes the paged admission path
    (quantize_raw_paged) writes, under any cfg dtype."""
    cfg = get_config("codellama-7b", smoke=True).with_(
        dtype=dtype, kv_quant=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    prompt = jnp.arange(3, 12)[None]                      # [1, 9]
    t = prompt.shape[1]
    # contiguous slab prefill
    _, slab = api.prefill_fn(params, {"tokens": prompt}, cfg, 16,
                             backend="xla")
    assert slab["layers"]["k"].dtype == jnp.int8
    # paged-admission reference: raw prefix KV, quantized per row
    _, raw = api.prefill_fn(params, {"tokens": prompt}, cfg, 16,
                            backend="xla", raw_cache=True)
    raw = {"layers": {k: v for k, v in raw["layers"].items() if k != "lens"}}
    qraw = api.quantize_raw_paged(raw, cfg)
    for leaf in ("k", "v"):  # int8 codes: bitwise identical
        np.testing.assert_array_equal(
            np.asarray(slab["layers"][leaf][:, :, :t]),
            np.asarray(qraw["layers"][leaf]))
    for leaf in ("k_s", "v_s"):  # f32 scales: same rows modulo XLA fusion ulps
        np.testing.assert_allclose(
            np.asarray(slab["layers"][leaf][:, :, :t]),
            np.asarray(qraw["layers"][leaf]), rtol=1e-6, atol=0)
    # and decode off that slab works end to end
    lg, _ = api.decode_fn(
        params, {"token": jnp.asarray([[5]], jnp.int32),
                 "position": jnp.asarray([t], jnp.int32)},
        slab, cfg, backend="xla")
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all())


def test_int8_engine_greedy_matches_contiguous_reference():
    """With kv_quant on, the paged engine and the contiguous-slab greedy
    reference see identical int8 codes+scales → identical tokens."""
    cfg = get_config("codellama-7b", smoke=True).with_(
        dtype="float32", kv_quant=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i, prompt=rng.integers(2, cfg.vocab_size,
                                               size=(5, 9)[i % 2]).astype(np.int32),
                    max_tokens=4) for i in range(3)]
    eng = ServingEngine(params, cfg, batch_size=2, max_seq=32, page_size=8,
                        backend="xla")
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.completed == 3
    for r in reqs:
        assert r.output == _greedy_ref(params, cfg, r.prompt, r.max_tokens, 32)


def test_paged_unsupported_families_raise():
    cfg = get_config("rwkv6-7b", smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    # the named construction-time error (a NotImplementedError subclass, so
    # pre-existing callers catching that still work)
    with pytest.raises(UnsupportedModelError, match="paged serving"):
        ServingEngine(params, cfg, batch_size=2, max_seq=32)
