"""Serve a smoke batch with forced preemption and export its Chrome trace.

    PYTHONPATH=src python scripts/trace_viewer.py [--out trace.json]
        [--arch codellama-7b] [--requests 6] [--summary]

Drives a small pool-constrained engine (tight page budget + an explicit
preemption) so the exported trace shows everything the observability
subsystem records: per-slot decode/prefill_chunk slices, pool-occupancy
counter samples, lifecycle instants, and the ``s``→``f`` flow arrow from
every preempt to its matching swap-in resume.  Open the JSON in
https://ui.perfetto.dev or ``chrome://tracing``.

Also usable as a library: ``drive_traced_engine()`` returns the drained
engine for tests/CI to export from.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import api  # noqa: E402
from repro.serving.engine import Request, ServingEngine  # noqa: E402
from repro.serving.trace import write_chrome_trace  # noqa: E402


def drive_traced_engine(arch: str = "codellama-7b", requests: int = 6,
                        seed: int = 0) -> ServingEngine:
    """Serve ``requests`` synthetic prompts on a smoke config with a pool
    tight enough that lazy growth must preempt — the trace gets real
    preempt→resume flow events, not just happy-path slices."""
    cfg = get_config(arch, smoke=True).with_(dtype="float32")
    params = api.init_model(jax.random.PRNGKey(seed), cfg)
    eng = ServingEngine(params, cfg, batch_size=3, max_seq=32, page_size=4,
                        num_pages=13, seed=seed, max_prefill_tokens=8,
                        backend="xla")
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab_size, 6 + i % 5)
                              .astype(np.int32),
                    max_tokens=10)
            for i in range(requests)]
    for r in reqs:
        eng.submit(r)
    # run a few steps, then force one preemption so the flow-event path is
    # exercised even if organic pool pressure never bites at smoke scale
    for _ in range(4):
        eng.step()
    victims = [i for i in eng._active_slots()
               if eng.pos[i] >= eng.pref_target[i]]
    if victims:
        eng._preempt(victims[-1])
    eng.run_until_drained(max_steps=500)
    return eng


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="trace.json")
    ap.add_argument("--arch", default="codellama-7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--summary", action="store_true",
                    help="print an event-count summary of the written trace")
    args = ap.parse_args(argv)

    eng = drive_traced_engine(args.arch, args.requests)
    obj = write_chrome_trace(args.out, eng.trace, n_slots=eng.B)
    evs = obj["traceEvents"]
    flows = sum(1 for e in evs if e["ph"] == "s")
    print(f"wrote {len(evs)} trace events ({flows} preempt->resume flows) "
          f"to {args.out} — open in https://ui.perfetto.dev")
    if args.summary:
        by_ph: dict = {}
        for e in evs:
            by_ph[e["ph"]] = by_ph.get(e["ph"], 0) + 1
        snap = eng.metrics_snapshot()
        print("events by phase:", json.dumps(by_ph, sort_keys=True))
        print("latency p50/p99 (ms):", {
            k: [round(v["p50"] * 1e3, 2), round(v["p99"] * 1e3, 2)]
            for k, v in snap["latency"].items()})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
