"""Generate the EXPERIMENTS.md roofline/dry-run tables from results/dryrun_opt/*.json."""
import json, glob, sys
from pathlib import Path

rows = []
for f in sorted(glob.glob("results/dryrun_opt/*.json")):
    d = json.loads(Path(f).read_text())
    if d.get("tag"):
        continue
    rows.append(d)

def fmt(v, n=3):
    return f"{v:.{n}g}" if isinstance(v, (int, float)) else str(v)

def table(mesh):
    out = ["| arch | shape | step | t_comp (s) | t_mem (s) | t_coll (s) | bottleneck | useful/HLO | roofline frac | bytes/dev (args+tmp) |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d["mesh"] != mesh:
            continue
        if d.get("skipped"):
            out.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | SKIP | — | — | {d.get('reason','')[:40]} |")
            continue
        if not d.get("ok"):
            out.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | FAIL | — | — | {d.get('error','')[:40]} |")
            continue
        r = d["roofline"]; m = d.get("memory", {})
        bpd = (m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)) / 2**30
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['step']} | {fmt(r['t_compute_s'])} | "
            f"{fmt(r['t_memory_s'])} | {fmt(r['t_collective_s'])} | {r['bottleneck']} | "
            f"{fmt(r['useful_flops_ratio'])} | {fmt(r['roofline_fraction'])} | {bpd:.2f} GiB |")
    return "\n".join(out)

print("### Single-pod mesh (16×16 = 256 chips)\n")
print(table("single"))
print("\n### Multi-pod mesh (2×16×16 = 512 chips)\n")
print(table("multi"))

# summary stats
ok = [d for d in rows if d.get("ok") and not d.get("skipped")]
fails = [d for d in rows if not d.get("ok")]
skips = [d for d in rows if d.get("skipped")]
print(f"\ncells: {len(ok)} compiled OK, {len(skips)} skipped per assignment, {len(fails)} failed")
