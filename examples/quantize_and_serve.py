"""End-to-end driver (the paper's deployment story): load an FP checkpoint,
quantize-on-load with SmoothQuant+, serve batched requests with continuous
batching over a paged KV cache (length-bucketed joint prefill, per-slot
sampling), and report throughput/TTFT/latency vs the FP16 engine — the
offline analog of paper Fig. 7.

    PYTHONPATH=src python examples/quantize_and_serve.py
"""
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import QuantConfig
from repro.core.calibration import synthetic_calibration_set
from repro.models import api
from repro.serving.engine import Request, ServingEngine, load_or_quantize

cfg = get_config("codellama-7b", smoke=True).with_(dtype="float32")
params = api.init_model(jax.random.PRNGKey(0), cfg)
calib = synthetic_calibration_set(cfg, n_seqs=2, seq_len=24)
artifact = tempfile.mkdtemp() + "/ptq"          # quantize once ...
t0 = time.perf_counter()
qparams, report = load_or_quantize(params, cfg, calib, QuantConfig(group_size=16),
                                   artifact_dir=artifact)
t_quant = time.perf_counter() - t0
t0 = time.perf_counter()                        # ... serve many: artifact boot
qparams, _ = load_or_quantize(None, cfg, None, QuantConfig(group_size=16),
                              artifact_dir=artifact)
print(f"quantized (alpha={report.alpha:.2f}) in {t_quant:.2f}s; "
      f"artifact re-boot in {time.perf_counter() - t0:.2f}s; serving...")

rng = np.random.default_rng(0)
def make_requests(n=10):
    # all requests enqueue at once (arrival_t is stamped at submit time);
    # TTFT then measures queueing + bucketed prefill, the tentpole's win
    return [Request(uid=i, prompt=rng.integers(2, cfg.vocab_size, 10).astype(np.int32),
                    max_tokens=8) for i in range(n)]

for tag, p in (("fp", params), ("w4a16", qparams)):
    eng = ServingEngine(p, cfg, batch_size=4, max_seq=64, page_size=16,
                        backend="xla")
    reqs = make_requests()
    t0 = time.perf_counter()
    for r in reqs:
        r.arrival_t = t0
        eng.submit(r)
    stats = eng.run_until_drained()
    dt = time.perf_counter() - t0
    lat = np.mean([(r.done_t - r.first_token_t) / max(len(r.output) - 1, 1)
                   for r in reqs if r.done_t and r.first_token_t]) * 1e3
    ttft = np.mean([r.first_token_t - r.arrival_t for r in reqs]) * 1e3
    print(f"[{tag:6s}] {stats.completed} reqs, {stats.decoded_tokens} tokens "
          f"in {dt:.2f}s -> {stats.decoded_tokens/dt:.1f} tok/s, "
          f"ttft {ttft:.1f} ms, {lat:.1f} ms/token "
          f"({stats.prefill_batches} joint prefills)")
