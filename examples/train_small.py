"""Train a small LM for a few hundred steps on the synthetic pipeline, with
checkpoint/restore round trip (fault-tolerance demo).

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import sys

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

losses = train_main([
    "--arch", "codellama-7b", "--smoke", "--steps", str(args.steps),
    "--batch", "8", "--seq", "64", "--lr", "3e-3",
    "--ckpt-dir", "/tmp/repro_ckpt", "--ckpt-every", "100",
])
assert losses[-1] < losses[0], "loss did not improve"
print("resuming from checkpoint for 10 more steps (restart demo)...")
train_main([
    "--arch", "codellama-7b", "--smoke", "--steps", str(args.steps + 10),
    "--batch", "8", "--seq", "64", "--lr", "3e-3",
    "--ckpt-dir", "/tmp/repro_ckpt",
])
print("OK")
