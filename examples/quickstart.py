"""Quickstart: SmoothQuant+ 4-bit PTQ of a small Code Llama-style model.

    PYTHONPATH=src python examples/quickstart.py

1. init an FP model, 2. calibrate + search alpha + smooth + int4-quantize,
3. compare quantized vs FP outputs, 4. generate a few tokens W4A16.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import QuantConfig
from repro.core.apply import smoothquant_plus
from repro.core.calibration import synthetic_calibration_set
from repro.models import api

cfg = get_config("codellama-7b", smoke=True).with_(dtype="float32")
params = api.init_model(jax.random.PRNGKey(0), cfg)
print(f"model: {cfg.name}  params: "
      f"{sum(x.size for x in jax.tree.leaves(params)):,}")

calib = synthetic_calibration_set(cfg, n_seqs=4, seq_len=32)
qparams, report = smoothquant_plus(
    params, cfg, calib, QuantConfig(group_size=16), step=0.25, verbose=True)
print(f"searched alpha={report.alpha:.2f}  whole-model loss={report.search_loss:.5f}")
print(f"linear weights: {report.fp_bytes/1e6:.2f} MB fp16-equiv -> "
      f"{report.quant_bytes/1e6:.2f} MB int4 "
      f"({report.quant_bytes/report.fp_bytes:.0%})")

batch = calib[0]
fp = api.forward_fn(params, batch, cfg, backend="xla")
w4 = api.forward_fn(qparams, batch, cfg, backend="xla")
rel = float(jnp.linalg.norm(w4 - fp) / jnp.linalg.norm(fp))
print(f"relative logit error after PTQ: {rel:.4f}")

# greedy generation with the quantized model
prompt = jnp.asarray([[5, 17, 300, 42]], jnp.int32)
logits, cache = api.prefill_fn(qparams, {"tokens": prompt}, cfg, 32, backend="xla")
toks = [int(jnp.argmax(logits, -1)[0])]
pos = prompt.shape[1]
for _ in range(8):
    logits, cache = api.decode_fn(
        qparams, {"token": jnp.asarray([[toks[-1]]], jnp.int32),
                  "position": jnp.asarray([pos], jnp.int32)},
        cache, cfg, backend="xla")
    toks.append(int(jnp.argmax(logits, -1)[0]))
    pos += 1
print("generated (W4A16):", toks)
