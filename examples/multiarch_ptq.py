"""Run SmoothQuant+ across every assigned architecture family (smoke scale)
and print the per-arch quantization report — shows the technique is wired
first-class through dense / MoE / MLA / hybrid / RWKV / enc-dec models.

    PYTHONPATH=src python examples/multiarch_ptq.py
"""
import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import QuantConfig
from repro.core.apply import smoothquant_plus
from repro.core.calibration import synthetic_calibration_set
from repro.models import api

for arch in ARCH_IDS[:10]:
    cfg = get_config(arch, smoke=True).with_(dtype="float32")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    calib = synthetic_calibration_set(cfg, n_seqs=2, seq_len=24)
    qp, rep = smoothquant_plus(params, cfg, calib, QuantConfig(group_size=16),
                               step=0.5)
    print(f"{arch:24s} alpha={rep.alpha:.2f} "
          f"quantized={len(rep.quantized_paths):3d} weight groups  "
          f"{rep.fp_bytes/1e6:7.2f}MB -> {rep.quant_bytes/1e6:7.2f}MB")
